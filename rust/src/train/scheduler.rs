//! Learning-rate schedule: linear warmup + cosine decay (paper §4.1:
//! "cosine scheduler applied and a 2000 step warm-up").
//!
//! The LR is an *input* to the compiled train step, so the schedule lives
//! entirely on the Rust side and can be changed without re-lowering.

#[derive(Clone, Copy, Debug)]
pub struct CosineSchedule {
    pub peak_lr: f64,
    pub min_lr: f64,
    pub warmup_steps: u64,
    pub total_steps: u64,
}

impl CosineSchedule {
    pub fn new(peak_lr: f64, min_lr: f64, warmup_steps: u64, total_steps: u64) -> Self {
        assert!(total_steps > 0);
        CosineSchedule {
            peak_lr,
            min_lr,
            warmup_steps: warmup_steps.min(total_steps),
            total_steps,
        }
    }

    /// LR for 0-based step `t` (the value used *during* step t).
    pub fn lr(&self, t: u64) -> f64 {
        if self.warmup_steps > 0 && t < self.warmup_steps {
            // linear ramp ending at peak on the last warmup step
            return self.peak_lr * (t + 1) as f64 / self.warmup_steps as f64;
        }
        let span = (self.total_steps - self.warmup_steps).max(1) as f64;
        let progress = ((t - self.warmup_steps) as f64 / span).min(1.0);
        let cos = 0.5 * (1.0 + (std::f64::consts::PI * progress).cos());
        self.min_lr + (self.peak_lr - self.min_lr) * cos
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn warmup_ramps_to_peak() {
        let s = CosineSchedule::new(1e-3, 1e-5, 10, 100);
        assert!((s.lr(0) - 1e-4).abs() < 1e-12);
        assert!((s.lr(9) - 1e-3).abs() < 1e-12);
        for t in 1..10 {
            assert!(s.lr(t) > s.lr(t - 1));
        }
    }

    #[test]
    fn cosine_decays_to_min() {
        let s = CosineSchedule::new(1e-3, 1e-5, 10, 100);
        assert!((s.lr(10) - 1e-3).abs() < 1e-5);
        for t in 11..100 {
            assert!(s.lr(t) <= s.lr(t - 1) + 1e-15);
        }
        assert!((s.lr(99) - 1e-5).abs() < 2e-6, "{}", s.lr(99));
        // past the end stays at min
        assert!((s.lr(500) - 1e-5).abs() < 1e-12);
    }

    #[test]
    fn halfway_point_is_midpoint() {
        let s = CosineSchedule::new(2e-3, 0.0, 0, 100);
        assert!((s.lr(50) - 1e-3).abs() < 1e-4);
    }

    #[test]
    fn zero_warmup_starts_at_peak() {
        let s = CosineSchedule::new(1e-3, 0.0, 0, 10);
        assert!((s.lr(0) - 1e-3).abs() < 1e-9);
    }
}
