//! The training-loop driver: data pipeline → compiled train step → metrics.
//!
//! This is the L3 hot loop. Per step: receive a prefetched batch, compute
//! the scheduled LR, derive the SR seed, execute the AOT train step, record
//! metrics. Periodically (and at the end) it sweeps the dev split for the
//! dev loss the paper's Fig. 3 reports.

use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::config::TrainConfig;
use crate::data::{loader, Pipeline};
use crate::obs::quant::QuantStepRecord;
use crate::obs::trace;
use crate::obs::TrainObs;
use crate::quant::sr::hash_u32;
use crate::runtime::{GradReducer, Manifest, State, VariantRuntime};

use super::metrics::{RunMetrics, StepRecord};
use super::scheduler::CosineSchedule;

/// Derive the per-step SR seed from (run seed, step): a single u32 the
/// graph further hashes per tensor.
pub fn step_seed(run_seed: u64, step: u64) -> u32 {
    hash_u32(step as u32, (run_seed as u32) ^ ((run_seed >> 32) as u32))
}

/// One rank's view of a distributed data-parallel run: who it is, the
/// gradient reducer the sharded train step calls between backward and the
/// optimizer, and the periodic collective weight resync. `Trainer`
/// ([`Trainer::run_sharded`]) drives the exchange without knowing what
/// transport is behind it — `dist::DistExchange` implements it over TCP,
/// and the same type over `Collective::solo()` is the world-1 reference.
pub trait StepExchange {
    fn rank(&self) -> usize;
    fn world(&self) -> usize;
    /// The reducer handed to [`crate::runtime::Backend::train_step_sharded`].
    fn reducer(&mut self) -> &mut dyn GradReducer;
    /// Collective weight-resync hook, called after every completed step;
    /// implementations own the cadence (`DistConfig::sync_every`).
    /// Returns the wire bytes this rank shipped or received (0 = no sync
    /// this step).
    fn sync_state(&mut self, manifest: &Manifest, state: &mut State, step: u64)
        -> Result<u64>;
}

pub struct Trainer<'a> {
    pub vrt: &'a VariantRuntime,
    pub pipeline: &'a Pipeline,
    pub cfg: TrainConfig,
    /// optional live progress callback (step, loss)
    pub progress: Option<Box<dyn FnMut(u64, f32) + 'a>>,
    /// observability handle: default-on pure atomics; `--metrics-addr`
    /// serves its registry, `--watch-addr` streams its step frames
    /// (see `docs/OBSERVABILITY.md`)
    pub obs: Arc<TrainObs>,
}

impl<'a> Trainer<'a> {
    pub fn new(vrt: &'a VariantRuntime, pipeline: &'a Pipeline, cfg: TrainConfig) -> Self {
        Trainer {
            vrt,
            pipeline,
            cfg,
            progress: None,
            obs: Arc::new(TrainObs::new()),
        }
    }

    /// Mean dev loss under the compiled eval step.
    pub fn dev_loss(&self, state: &State, ternary: bool) -> Result<f32> {
        let m = self.vrt.manifest();
        let batches = loader::dev_batches(&self.pipeline.dataset, m.variant.model.batch_size);
        let mut nll = 0f64;
        let mut count = 0f64;
        for b in &batches {
            let (s, c) = self.vrt.eval_step(state, &b.tokens, ternary)?;
            nll += s as f64;
            count += c as f64;
        }
        Ok(if count > 0.0 { (nll / count) as f32 } else { f32::NAN })
    }

    /// Run the configured number of steps from a fresh init.
    pub fn run(&mut self) -> Result<(State, RunMetrics)> {
        let state = self.vrt.init_state(self.cfg.seed as u32)?;
        self.run_from(state)
    }

    /// Run from an existing state (checkpoint resume).
    pub fn run_from(&mut self, mut state: State) -> Result<(State, RunMetrics)> {
        let m = self.vrt.manifest();
        let cfg = self.cfg.clone();
        let sched = CosineSchedule::new(cfg.peak_lr, cfg.min_lr, cfg.warmup_steps, cfg.steps);
        let start_step = state.step() as u64;
        let loader = self.pipeline.loader(
            m.variant.model.batch_size,
            cfg.steps.saturating_sub(start_step),
            cfg.seed,
        );
        let mut metrics = RunMetrics::new(&m.variant.variant_name, &cfg.dataset);
        self.obs
            .on_run_start(&m.variant.variant_name, &cfg.dataset, 1, cfg.steps);
        // Pre-size the per-layer quant-health slots once from the manifest so
        // the per-step recording pass stays allocation-free (see obs/quant.rs).
        let qlayers = self.vrt.quant_layers();
        let mut qrec = QuantStepRecord::new(qlayers.len());
        if !qlayers.is_empty() {
            self.obs.init_quant(&qlayers);
        }
        let wall = Instant::now();
        loop {
            // train.step covers fetch → metrics; data_load is the fetch
            // (record_interval is a no-op unless --trace-out is set)
            let step_start = Instant::now();
            let Some(batch) = loader.next() else { break };
            trace::record_interval(
                "train",
                trace::names::TRAIN_DATA_LOAD,
                step_start,
                Instant::now(),
            );
            let step = start_step + batch.step;
            let lr = sched.lr(step) as f32;
            let seed = step_seed(cfg.seed, step);
            let t0 = Instant::now();
            qrec.reset();
            let tap = (!qlayers.is_empty()).then_some(&mut qrec);
            let (new_state, sm) = self.vrt.train_step_quant(state, &batch.tokens, seed, lr, tap)?;
            state = new_state;
            let rec = StepRecord {
                step,
                loss: sm.loss,
                lr,
                upd_frac: sm.upd_frac,
                gnorm: sm.gnorm,
                step_ms: t0.elapsed().as_secs_f32() * 1e3,
            };
            self.obs.on_step(&rec, sm.fwd_ms, sm.opt_ms);
            if !qlayers.is_empty() {
                self.obs.on_quant(step, &qrec);
            }
            trace::record_interval("train", trace::names::TRAIN_STEP, step_start, Instant::now());
            if cfg.log_every > 0 && step % cfg.log_every == 0 {
                if let Some(cb) = self.progress.as_mut() {
                    cb(step, sm.loss);
                }
            }
            metrics.push(rec);
            if cfg.eval_every > 0 && step > 0 && step % cfg.eval_every == 0 {
                let dl = self.dev_loss(&state, false)?;
                self.obs.on_dev_loss(dl);
                metrics.dev_losses.push((step, dl));
            }
        }
        metrics.final_dev_loss = Some(self.dev_loss(&state, false)?);
        metrics.wall_secs = wall.elapsed().as_secs_f64();
        self.obs
            .on_run_end(metrics.final_dev_loss, metrics.wall_secs);
        Ok((state, metrics))
    }

    /// Run the configured number of steps as one rank of a distributed
    /// data-parallel job: every rank initializes the identical state
    /// (same seed), consumes its contiguous shard band of the global
    /// batch stream, and steps through
    /// [`crate::runtime::Backend::train_step_sharded`] with the
    /// exchange's reducer — so all ranks hold bit-identical states at
    /// every step and this method's result on *any* rank equals the
    /// 1-worker run's. Metrics (including the final dev loss) are
    /// computed on every rank for the same reason; rank 0 is the one
    /// that persists them.
    pub fn run_sharded(&mut self, ex: &mut dyn StepExchange) -> Result<(State, RunMetrics)> {
        let m = self.vrt.manifest();
        let cfg = self.cfg.clone();
        let rows = m.variant.model.batch_size;
        let band = crate::config::shard_band(ex.world(), ex.rank(), rows)?;
        let sched = CosineSchedule::new(cfg.peak_lr, cfg.min_lr, cfg.warmup_steps, cfg.steps);
        let mut state = self.vrt.init_state(cfg.seed as u32)?;
        let loader = self
            .pipeline
            .loader_sharded(rows, cfg.steps, cfg.seed, band);
        let mut metrics = RunMetrics::new(&m.variant.variant_name, &cfg.dataset);
        self.obs.on_run_start(
            &m.variant.variant_name,
            &cfg.dataset,
            ex.world() as u32,
            cfg.steps,
        );
        let qlayers = self.vrt.quant_layers();
        let mut qrec = QuantStepRecord::new(qlayers.len());
        if !qlayers.is_empty() {
            self.obs.init_quant(&qlayers);
        }
        let wall = Instant::now();
        loop {
            let step_start = Instant::now();
            let Some(batch) = loader.next() else { break };
            trace::record_interval(
                "train",
                trace::names::TRAIN_DATA_LOAD,
                step_start,
                Instant::now(),
            );
            let step = batch.step;
            let lr = sched.lr(step) as f32;
            let seed = step_seed(cfg.seed, step);
            let t0 = Instant::now();
            qrec.reset();
            let tap = (!qlayers.is_empty()).then_some(&mut qrec);
            let (new_state, sm) = self.vrt.train_step_sharded_quant(
                state,
                &batch.tokens,
                band,
                rows,
                step,
                seed,
                lr,
                ex.reducer(),
                tap,
            )?;
            state = new_state;
            ex.sync_state(m, &mut state, step)?;
            let rec = StepRecord {
                step,
                loss: sm.loss,
                lr,
                upd_frac: sm.upd_frac,
                gnorm: sm.gnorm,
                step_ms: t0.elapsed().as_secs_f32() * 1e3,
            };
            self.obs.on_step(&rec, sm.fwd_ms, sm.opt_ms);
            if !qlayers.is_empty() {
                self.obs.on_quant(step, &qrec);
            }
            trace::record_interval("train", trace::names::TRAIN_STEP, step_start, Instant::now());
            if cfg.log_every > 0 && step % cfg.log_every == 0 {
                if let Some(cb) = self.progress.as_mut() {
                    cb(step, sm.loss);
                }
            }
            metrics.push(rec);
            if cfg.eval_every > 0 && step > 0 && step % cfg.eval_every == 0 {
                let dl = self.dev_loss(&state, false)?;
                self.obs.on_dev_loss(dl);
                metrics.dev_losses.push((step, dl));
            }
        }
        metrics.final_dev_loss = Some(self.dev_loss(&state, false)?);
        metrics.wall_secs = wall.elapsed().as_secs_f64();
        self.obs
            .on_run_end(metrics.final_dev_loss, metrics.wall_secs);
        Ok((state, metrics))
    }
}

/// Convenience: train a variant end to end and persist metrics + checkpoint.
pub fn train_and_save(
    vrt: &VariantRuntime,
    pipeline: &Pipeline,
    cfg: TrainConfig,
    out_dir: &Path,
) -> Result<(State, RunMetrics)> {
    let mut tr = Trainer::new(vrt, pipeline, cfg);
    let (state, metrics) = tr.run()?;
    metrics.save(out_dir)?;
    tr.obs.save_quant_health(out_dir)?;
    super::checkpoint::save(
        &out_dir.join("model.dqt"),
        vrt.manifest(),
        &state,
        crate::quant::Format::F32,
        true,
    )?;
    Ok((state, metrics))
}
