//! Training subsystem: LR schedule, loop driver, metrics, checkpoints.

pub mod checkpoint;
pub mod metrics;
pub mod scheduler;
pub mod trainer;

pub use metrics::{RunMetrics, StepRecord};
pub use scheduler::CosineSchedule;
pub use trainer::{step_seed, train_and_save, StepExchange, Trainer};
