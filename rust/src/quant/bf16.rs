//! BF16 storage codec: round-to-nearest-even truncation of f32.
//!
//! Bit-exact with `jnp.bfloat16` casts in `python/compile/lowp.py` (golden
//! vectors shared between the two test suites).

/// Encode an f32 to its BF16 bit pattern (round-to-nearest-even).
pub fn encode(x: f32) -> u16 {
    let bits = x.to_bits();
    if x.is_nan() {
        return ((bits >> 16) | 0x0040) as u16; // quiet NaN, keep sign
    }
    // round to nearest even on the truncated 16 bits
    let round_bit = 0x0000_8000u32;
    let lsb = (bits >> 16) & 1;
    let rounded = bits.wrapping_add(0x0000_7FFF + lsb);
    let _ = round_bit;
    (rounded >> 16) as u16
}

/// Decode a BF16 bit pattern to f32 (exact).
pub fn decode(h: u16) -> f32 {
    f32::from_bits((h as u32) << 16)
}

/// Value-level cast: what an f32 becomes when stored as BF16.
pub fn cast(x: f32) -> f32 {
    decode(encode(x))
}

/// Cast a slice in place (storage simulation for the memory experiments).
pub fn cast_slice(xs: &mut [f32]) {
    for x in xs.iter_mut() {
        *x = cast(*x);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_values_survive() {
        // (note: fp16's 65504 is NOT bf16-exact — 11 significant bits)
        for v in [0.0f32, 1.0, -1.0, 0.5, 2.0, 65536.0, 3.140625] {
            assert_eq!(cast(v), v, "{v}");
        }
    }

    #[test]
    fn mantissa_rounding() {
        // 1 + 2^-9 not representable (7 mantissa bits) → rounds to 1.0
        assert_eq!(cast(1.0 + 2f32.powi(-9)), 1.0);
        // 1 + 2^-7 is representable
        assert_eq!(cast(1.0 + 2f32.powi(-7)), 1.0 + 2f32.powi(-7));
        // halfway: 1 + 3*2^-9 → nearest even of {1+2^-8, 1+2^-7}... verify idempotence
        let y = cast(1.0 + 3.0 * 2f32.powi(-9));
        assert_eq!(cast(y), y);
    }

    #[test]
    fn round_to_nearest_even() {
        // 1 + 2^-8 is exactly halfway between 1.0 and 1 + 2^-7 → ties to even (1.0)
        assert_eq!(cast(1.0 + 2f32.powi(-8)), 1.0);
        // 1 + 2^-7 + 2^-8 halfway between 1+2^-7 and 1+2^-6 → ties to even (1+2^-6)
        assert_eq!(
            cast(1.0 + 2f32.powi(-7) + 2f32.powi(-8)),
            1.0 + 2f32.powi(-6)
        );
    }

    #[test]
    fn negatives_and_inf() {
        assert_eq!(cast(-2.5), -2.5);
        assert_eq!(cast(f32::INFINITY), f32::INFINITY);
        assert_eq!(cast(f32::NEG_INFINITY), f32::NEG_INFINITY);
        assert!(cast(f32::NAN).is_nan());
    }

    #[test]
    fn idempotent() {
        for i in 0..1000 {
            let v = (i as f32 - 500.0) * 0.00137;
            let y = cast(v);
            assert_eq!(cast(y), y);
        }
    }
}
