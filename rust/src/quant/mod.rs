//! Numeric-format substrate: the storage codecs behind the paper's claims.
//!
//! The training graph (L2) computes on fake-quantized f32 values — exactly
//! as the paper does on GPUs (§A.1: "low-precision simulation"). This module
//! provides the *true* packed representations those values stand for, used
//! by checkpointing (`train::checkpoint`), deployment (ternary inference
//! from a 2-bit-packed file), the packed-grid host state
//! (`runtime::State`) and the memory model (Table 3 / Fig. 3):
//!
//! * [`codec`]   — the unified codec registry: [`codec::Format`] names every
//!                 storage format, [`codec::Codec`] implements it, and
//!                 [`codec::PackedTensor`] is the canonical packed tensor
//!                 value type shared by checkpointing, the runtime state and
//!                 the memory model. All format dispatch lives here — the
//!                 modules below are the per-format kernels it calls into.
//! * [`ternary`] — 2-bit packing of {-1, 0, +1} weights (16 weights / u32),
//!                 LUT-accelerated unpack
//! * [`intn`]    — INTn grids (n = 2..=8), streaming bit-packing
//! * [`fp8`]     — OCP FP8 E4M3/E5M2 encode/decode, bit-exact with
//!                 `python/compile/lowp.py`
//! * [`bf16`]    — BF16 round-to-nearest-even storage
//! * [`sr`]      — stochastic rounding on the host (checkpoint conversion +
//!                 the counter-hash PRNG shared with the Pallas kernel)
//! * [`gradcodec`] — SR + error-feedback gradient wire codec: int8/ternary
//!                 gradient frames for the distributed exchange (`dist/`)
//!
//! The paper's `bits == 1.58` ternary sentinel is interpreted in exactly
//! one place: [`codec::Format::from_bits`].

pub mod bf16;
pub mod codec;
pub mod fp8;
pub mod gradcodec;
pub mod intn;
pub mod sr;
pub mod ternary;

pub use codec::{Codec, Format, PackedTensor};
pub use gradcodec::{GradCodec, PackedGrad};

/// Integer grid range `[q_min, q_max]` for an n-bit format; `bits == 1.58`
/// selects the paper's ternary format {-1, 0, 1} (Eq. Qn/Qp in §3.2).
pub fn qrange(bits: f64) -> (f64, f64) {
    Format::from_bits(bits).grid_range()
}

/// AbsMean scale `s = Qp / mean(|w|)` (paper Eq. 3).
pub fn absmean_scale(w: &[f32], bits: f64) -> f32 {
    let (_, qp) = qrange(bits);
    let mean: f64 = w.iter().map(|x| x.abs() as f64).sum::<f64>() / w.len().max(1) as f64;
    (qp / (mean + 1e-8)) as f32
}

/// AbsMean quantization (paper Eq. 4): `clip(round(w*s), Qn, Qp) / s`.
pub fn absmean_quantize(w: &[f32], bits: f64, s: f32) -> Vec<f32> {
    let (qn, qp) = qrange(bits);
    w.iter()
        .map(|&x| ((x * s).round() as f64).clamp(qn, qp) as f32 / s)
        .collect()
}

/// Bits per weight of each storage format, for the memory model (reads the
/// codec registry; `1.58` maps to the practical 2-bit ternary packing).
pub fn bits_per_weight(bits: f64) -> f64 {
    Format::from_bits(bits).bits_per_weight()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn qrange_ternary_and_int() {
        assert_eq!(qrange(1.58), (-1.0, 1.0));
        assert_eq!(qrange(8.0), (-128.0, 127.0));
        assert_eq!(qrange(3.0), (-4.0, 3.0));
        assert_eq!(qrange(4.0), (-8.0, 7.0));
        assert_eq!(qrange(2.0), (-2.0, 1.0));
    }

    #[test]
    fn bits_per_weight_matches_registry() {
        assert_eq!(bits_per_weight(1.58), 2.0);
        assert_eq!(bits_per_weight(8.0), 8.0);
        assert_eq!(bits_per_weight(3.0), 3.0);
    }

    #[test]
    fn absmean_matches_paper_equations() {
        let w = [0.1f32, -0.2, 0.3, -0.4];
        let s = absmean_scale(&w, 1.58);
        // mean|w| = 0.25, Qp = 1 → s ≈ 4
        assert!((s - 4.0).abs() < 1e-3, "{s}");
        let q = absmean_quantize(&w, 1.58, s);
        // 0.1*4=0.4→0; -0.2*4=-0.8→-1; 0.3*4=1.2→1; -0.4*4=-1.6→-2 clip -1
        let expect = [0.0, -1.0 / s, 1.0 / s, -1.0 / s];
        for (a, b) in q.iter().zip(expect.iter()) {
            assert!((a - b).abs() < 1e-6, "{q:?}");
        }
    }

    #[test]
    fn quantized_values_on_grid() {
        let w: Vec<f32> = (0..100).map(|i| (i as f32 - 50.0) * 0.013).collect();
        for bits in [1.58, 3.0, 4.0, 8.0] {
            let s = absmean_scale(&w, bits);
            let (qn, qp) = qrange(bits);
            for v in absmean_quantize(&w, bits, s) {
                let k = (v * s) as f64;
                assert!((k - k.round()).abs() < 1e-3);
                assert!(k >= qn - 1e-3 && k <= qp + 1e-3);
            }
        }
    }
}
