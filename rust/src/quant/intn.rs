//! INTn grid storage (n = 2..=8): bit-packed signed integers.
//!
//! Stores the integer grid indices `k = w*s` of a DQT weight matrix.
//! Codes are two's-complement in `n` bits, packed LSB-first into a `u8`
//! stream (crossing byte boundaries, no padding except the final byte), so
//! an INT3 matrix really costs 3 bits/weight — matching the paper's memory
//! arithmetic in §1 (1B params × INT8 = 1 GB, ternary = 0.25 GB packed).
//!
//! Both directions stream through a word-sized bit accumulator instead of
//! testing individual bits, so packing an INT4 matrix moves 8 codes per
//! byte-flush rather than running a 4-iteration inner loop per code.

/// Pack signed integers into `bits`-wide two's-complement codes.
pub fn pack(values: &[i32], bits: u32) -> Result<Vec<u8>, String> {
    assert!((2..=8).contains(&bits), "bits must be in 2..=8");
    let lo = -(1i32 << (bits - 1));
    let hi = (1i32 << (bits - 1)) - 1;
    let mask = (1i32 << bits) - 1;
    let total_bits = values.len() * bits as usize;
    let mut out = Vec::with_capacity(total_bits.div_ceil(8));
    // bit accumulator: codes enter at `nbits`, full bytes drain from the
    // bottom — identical layout to the historical per-bit loop
    let mut acc = 0u32;
    let mut nbits = 0u32;
    for (i, &v) in values.iter().enumerate() {
        if v < lo || v > hi {
            return Err(format!("value {v} at {i} out of INT{bits} range [{lo},{hi}]"));
        }
        acc |= ((v & mask) as u32) << nbits;
        nbits += bits;
        while nbits >= 8 {
            out.push((acc & 0xFF) as u8);
            acc >>= 8;
            nbits -= 8;
        }
    }
    if nbits > 0 {
        out.push((acc & 0xFF) as u8);
    }
    Ok(out)
}

/// Unpack `n` signed integers from `bits`-wide codes.
pub fn unpack(packed: &[u8], n: usize, bits: u32) -> Vec<i32> {
    assert!((2..=8).contains(&bits));
    let mask = (1u32 << bits) - 1;
    let sign = 1u32 << (bits - 1);
    let wrap = 1i32 << bits;
    let mut out = Vec::with_capacity(n);
    let mut acc = 0u32;
    let mut nbits = 0u32;
    let mut next = packed.iter();
    for _ in 0..n {
        while nbits < bits {
            acc |= (*next.next().expect("packed stream too short") as u32) << nbits;
            nbits += 8;
        }
        let code = acc & mask;
        acc >>= bits;
        nbits -= bits;
        out.push(if code & sign != 0 {
            code as i32 - wrap
        } else {
            code as i32
        });
    }
    out
}

/// Packed size in bytes of `n` INTn values.
pub fn packed_bytes(n: usize, bits: u32) -> usize {
    (n * bits as usize).div_ceil(8)
}

/// Convenience: pack the grid indices of fake-quantized f32 values `w`
/// (values k/s) given their scale.
pub fn pack_grid(w: &[f32], s: f32, bits: u32) -> Result<Vec<u8>, String> {
    let k: Vec<i32> = w.iter().map(|&x| (x * s).round() as i32).collect();
    pack(&k, bits)
}

/// Inverse of [`pack_grid`].
pub fn unpack_grid(packed: &[u8], n: usize, s: f32, bits: u32) -> Vec<f32> {
    unpack(packed, n, bits).iter().map(|&k| k as f32 / s).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_all_widths() {
        for bits in 2..=8u32 {
            let lo = -(1i32 << (bits - 1));
            let hi = (1i32 << (bits - 1)) - 1;
            let vals: Vec<i32> = (0..300).map(|i| lo + (i % (hi - lo + 1))).collect();
            let p = pack(&vals, bits).unwrap();
            assert_eq!(p.len(), packed_bytes(vals.len(), bits));
            assert_eq!(unpack(&p, vals.len(), bits), vals, "bits={bits}");
        }
    }

    #[test]
    fn streaming_pack_matches_per_bit_reference() {
        // reference: the seed's bit-at-a-time packer
        fn pack_ref(values: &[i32], bits: u32) -> Vec<u8> {
            let total_bits = values.len() * bits as usize;
            let mut out = vec![0u8; total_bits.div_ceil(8)];
            for (i, &v) in values.iter().enumerate() {
                let code = (v & ((1i32 << bits) - 1)) as u32;
                let bit0 = i * bits as usize;
                for b in 0..bits as usize {
                    if code & (1 << b) != 0 {
                        out[(bit0 + b) / 8] |= 1 << ((bit0 + b) % 8);
                    }
                }
            }
            out
        }
        for bits in 2..=8u32 {
            let lo = -(1i32 << (bits - 1));
            let hi = (1i32 << (bits - 1)) - 1;
            for n in [1usize, 2, 3, 7, 8, 9, 63, 64, 65, 257] {
                let vals: Vec<i32> =
                    (0..n).map(|i| lo + (i as i32 * 7 % (hi - lo + 1))).collect();
                assert_eq!(
                    pack(&vals, bits).unwrap(),
                    pack_ref(&vals, bits),
                    "bits={bits} n={n}"
                );
            }
        }
    }

    #[test]
    fn range_checked() {
        assert!(pack(&[7], 4).is_ok());
        assert!(pack(&[8], 4).is_err());
        assert!(pack(&[-8], 4).is_ok());
        assert!(pack(&[-9], 4).is_err());
    }

    #[test]
    fn grid_roundtrip() {
        let s = 37.5f32;
        let w: Vec<f32> = (-128..128).map(|k| k as f32 / s).collect();
        let p = pack_grid(&w, s, 8).unwrap();
        let back = unpack_grid(&p, w.len(), s, 8);
        for (a, b) in w.iter().zip(back.iter()) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn int8_is_quarter_of_fp32() {
        assert_eq!(packed_bytes(1000, 8), 1000);
    }
}
