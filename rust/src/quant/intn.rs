//! INTn grid storage (n = 2..=8): bit-packed signed integers.
//!
//! Stores the integer grid indices `k = w*s` of a DQT weight matrix.
//! Codes are two's-complement in `n` bits, packed LSB-first into a `u8`
//! stream (crossing byte boundaries, no padding except the final byte), so
//! an INT3 matrix really costs 3 bits/weight — matching the paper's memory
//! arithmetic in §1 (1B params × INT8 = 1 GB, ternary = 0.25 GB packed).

/// Pack signed integers into `bits`-wide two's-complement codes.
pub fn pack(values: &[i32], bits: u32) -> Result<Vec<u8>, String> {
    assert!((2..=8).contains(&bits), "bits must be in 2..=8");
    let lo = -(1i32 << (bits - 1));
    let hi = (1i32 << (bits - 1)) - 1;
    let total_bits = values.len() * bits as usize;
    let mut out = vec![0u8; total_bits.div_ceil(8)];
    for (i, &v) in values.iter().enumerate() {
        if v < lo || v > hi {
            return Err(format!("value {v} at {i} out of INT{bits} range [{lo},{hi}]"));
        }
        let code = (v & ((1i32 << bits) - 1)) as u32;
        let bit0 = i * bits as usize;
        for b in 0..bits as usize {
            if code & (1 << b) != 0 {
                out[(bit0 + b) / 8] |= 1 << ((bit0 + b) % 8);
            }
        }
    }
    Ok(out)
}

/// Unpack `n` signed integers from `bits`-wide codes.
pub fn unpack(packed: &[u8], n: usize, bits: u32) -> Vec<i32> {
    assert!((2..=8).contains(&bits));
    (0..n)
        .map(|i| {
            let bit0 = i * bits as usize;
            let mut code = 0u32;
            for b in 0..bits as usize {
                if packed[(bit0 + b) / 8] & (1 << ((bit0 + b) % 8)) != 0 {
                    code |= 1 << b;
                }
            }
            // sign-extend
            let sign = 1u32 << (bits - 1);
            if code & sign != 0 {
                (code as i32) - (1i32 << bits)
            } else {
                code as i32
            }
        })
        .collect()
}

/// Packed size in bytes of `n` INTn values.
pub fn packed_bytes(n: usize, bits: u32) -> usize {
    (n * bits as usize).div_ceil(8)
}

/// Convenience: pack the grid indices of fake-quantized f32 values `w`
/// (values k/s) given their scale.
pub fn pack_grid(w: &[f32], s: f32, bits: u32) -> Result<Vec<u8>, String> {
    let k: Vec<i32> = w.iter().map(|&x| (x * s).round() as i32).collect();
    pack(&k, bits)
}

/// Inverse of [`pack_grid`].
pub fn unpack_grid(packed: &[u8], n: usize, s: f32, bits: u32) -> Vec<f32> {
    unpack(packed, n, bits).iter().map(|&k| k as f32 / s).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_all_widths() {
        for bits in 2..=8u32 {
            let lo = -(1i32 << (bits - 1));
            let hi = (1i32 << (bits - 1)) - 1;
            let vals: Vec<i32> = (0..300).map(|i| lo + (i % (hi - lo + 1))).collect();
            let p = pack(&vals, bits).unwrap();
            assert_eq!(p.len(), packed_bytes(vals.len(), bits));
            assert_eq!(unpack(&p, vals.len(), bits), vals, "bits={bits}");
        }
    }

    #[test]
    fn range_checked() {
        assert!(pack(&[7], 4).is_ok());
        assert!(pack(&[8], 4).is_err());
        assert!(pack(&[-8], 4).is_ok());
        assert!(pack(&[-9], 4).is_err());
    }

    #[test]
    fn grid_roundtrip() {
        let s = 37.5f32;
        let w: Vec<f32> = (-128..128).map(|k| k as f32 / s).collect();
        let p = pack_grid(&w, s, 8).unwrap();
        let back = unpack_grid(&p, w.len(), s, 8);
        for (a, b) in w.iter().zip(back.iter()) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn int8_is_quarter_of_fp32() {
        assert_eq!(packed_bytes(1000, 8), 1000);
    }
}
