//! 2-bit packing of ternary {-1, 0, +1} weights — the deployment format.
//!
//! Encoding per trit: `0b00` = 0, `0b01` = +1, `0b10` = -1 (`0b11` unused,
//! decoded as 0). 16 trits per `u32`, little-endian within the word. A
//! 1B-parameter ternary model packs to 0.25 GB vs 4 GB in FP32 — the 16×
//! reduction the paper's introduction cites.
//!
//! The hot paths are vectorized: `pack` accumulates a whole word before
//! storing (no per-trit index arithmetic), and `unpack` expands four trits
//! at a time through a 256-entry byte→`[f32; 4]` lookup table.

use std::sync::OnceLock;

/// Decoded value of each 2-bit code (`0b11` falls back to 0, matching the
/// historical per-trit decoder).
const CODE_VALUES: [f32; 4] = [0.0, 1.0, -1.0, 0.0];

/// byte → the four trit values it encodes (LSB-first pairs).
fn byte_lut() -> &'static [[f32; 4]; 256] {
    static LUT: OnceLock<[[f32; 4]; 256]> = OnceLock::new();
    LUT.get_or_init(|| {
        let mut t = [[0.0f32; 4]; 256];
        for (b, row) in t.iter_mut().enumerate() {
            for (j, slot) in row.iter_mut().enumerate() {
                *slot = CODE_VALUES[(b >> (2 * j)) & 0b11];
            }
        }
        t
    })
}

/// Pack ternary values (given as f32 in {-1.0, 0.0, +1.0}) into 2-bit codes.
///
/// Values are snapped with `round()`; anything outside {-1,0,1} after
/// rounding is an error (the caller must pass grid values).
pub fn pack(values: &[f32]) -> Result<Vec<u32>, String> {
    let mut out = Vec::with_capacity(values.len().div_ceil(16));
    let mut word = 0u32;
    let mut shift = 0u32;
    for (i, &v) in values.iter().enumerate() {
        let code: u32 = match v.round() as i32 {
            0 => 0b00,
            1 => 0b01,
            -1 => 0b10,
            _ => return Err(format!("value {v} at {i} is not ternary")),
        };
        word |= code << shift;
        shift += 2;
        if shift == 32 {
            out.push(word);
            word = 0;
            shift = 0;
        }
    }
    if shift > 0 {
        out.push(word);
    }
    Ok(out)
}

/// Unpack `n` ternary values from 2-bit codes (LUT-based, 4 trits/step).
/// Panics if `packed` holds fewer than `n` trits (like the seed's
/// index-out-of-bounds, but with a message).
pub fn unpack(packed: &[u32], n: usize) -> Vec<f32> {
    assert!(
        packed.len() * 16 >= n,
        "packed ternary stream holds {} trits, {n} requested",
        packed.len() * 16
    );
    let lut = byte_lut();
    let mut out = Vec::with_capacity(n);
    // bulk: words whose 16 trits are all wanted — pre-sliced, so the hot
    // loop carries no remaining-count branch per byte
    let full_words = n / 16;
    for &word in &packed[..full_words] {
        for b in word.to_le_bytes() {
            out.extend_from_slice(&lut[b as usize]);
        }
    }
    // tail: at most one partially-consumed word
    let mut rem = n - full_words * 16;
    if rem > 0 {
        let mut bytes = packed[full_words].to_le_bytes().into_iter();
        while rem >= 4 {
            out.extend_from_slice(&lut[bytes.next().unwrap() as usize]);
            rem -= 4;
        }
        if rem > 0 {
            out.extend_from_slice(&lut[bytes.next().unwrap() as usize][..rem]);
        }
    }
    out
}

/// Packed size in bytes for `n` ternary weights.
pub fn packed_bytes(n: usize) -> usize {
    n.div_ceil(16) * 4
}

/// Decoded value of the trit at absolute index `i` in the packed stream.
#[inline]
fn trit_at(packed: &[u32], i: usize) -> f32 {
    CODE_VALUES[((packed[i / 16] >> ((i % 16) * 2)) & 0b11) as usize]
}

/// Fused byte-LUT dot products of packed weight rows `r0..r0+rows`
/// against every row of `x[M, k]`, written *transposed*:
/// `out[(r - r0) * m + bi] = (W_r · x_bi) * inv_s`.
///
/// This is the arithmetic core the kernel layer partitions: the dot
/// products run straight off the 2-bit codes (four trits per byte through
/// the 256-entry LUT — no f32 weight materialization anywhere), and the
/// per-(row, batch) accumulation order is fixed by the code stream walk,
/// so callers may split the row range freely without changing one bit of
/// the result. Matches `unpack` on the unused `0b11` code (decoded as 0).
#[allow(clippy::too_many_arguments)]
pub(crate) fn dot_rows(
    packed: &[u32],
    x: &[f32],
    m: usize,
    k: usize,
    r0: usize,
    rows: usize,
    inv_s: f32,
    out: &mut [f32],
) {
    debug_assert_eq!(out.len(), rows * m);
    let lut = byte_lut();
    let mut acc = vec![0f32; m];
    for rr in 0..rows {
        let r = r0 + rr;
        acc.fill(0.0);
        let mut t = r * k; // absolute trit index
        let mut j = 0; // column within the row
        // head: trits before the next byte boundary (rows with k % 4 != 0)
        while j < k && t % 4 != 0 {
            let w = trit_at(packed, t);
            if w != 0.0 {
                for (bi, a) in acc.iter_mut().enumerate() {
                    *a += w * x[bi * k + j];
                }
            }
            j += 1;
            t += 1;
        }
        // bulk: four trits per byte through the LUT
        while j + 4 <= k {
            let byte = ((packed[t / 16] >> ((t % 16) * 2)) & 0xFF) as usize;
            if byte != 0 {
                let w = &lut[byte];
                for (bi, a) in acc.iter_mut().enumerate() {
                    let xr = &x[bi * k + j..bi * k + j + 4];
                    *a += w[0] * xr[0] + w[1] * xr[1] + w[2] * xr[2] + w[3] * xr[3];
                }
            }
            j += 4;
            t += 4;
        }
        // tail
        while j < k {
            let w = trit_at(packed, t);
            if w != 0.0 {
                for (bi, a) in acc.iter_mut().enumerate() {
                    *a += w * x[bi * k + j];
                }
            }
            j += 1;
            t += 1;
        }
        for (bi, a) in acc.iter().enumerate() {
            out[rr * m + bi] = a * inv_s;
        }
    }
}

/// Fast-tier table build for the activation-block LUT GEMM (`k % 4 == 0`
/// only — every weight row starts byte-aligned): for activation block
/// `bj` (columns `4*bj..4*bj+4`) and every possible weight byte `b`,
///
/// `out[((bj - b0) * 256 + b) * m + bi] = Σ_{t<4} decode(b, t) · x[bi, 4*bj + t]`
///
/// i.e. the partial dot sum that byte contributes to batch row `bi`.
/// Built once per GEMM call and amortized over every output channel.
/// Entries are filled by the prefix recurrence — an entry is a
/// previously-filled entry (byte with the top trit cleared) plus one
/// signed activation — so a block-row costs 255 madds per batch row, not
/// 256×4. Entry values depend only on `x`, never on how the block range
/// is partitioned, so parallel builds are deterministic.
pub(crate) fn block_tables(x: &[f32], m: usize, k: usize, b0: usize, out: &mut [f32]) {
    debug_assert_eq!(k % 4, 0);
    debug_assert_eq!(out.len() % (256 * m), 0);
    for (bl, tb) in out.chunks_mut(256 * m).enumerate() {
        let bj = b0 + bl;
        for bi in 0..m {
            let xb = &x[bi * k + 4 * bj..bi * k + 4 * bj + 4];
            tb[bi] = 0.0; // byte 0b00000000 decodes to four zeros
            for (p, &xv) in xb.iter().enumerate() {
                let filled = 1usize << (2 * p); // complete prefixes so far
                for code in 1usize..4 {
                    let v = CODE_VALUES[code] * xv;
                    for base in 0..filled {
                        tb[((code << (2 * p)) | base) * m + bi] = tb[base * m + bi] + v;
                    }
                }
            }
        }
    }
}

/// Fast-tier fused dot products over prebuilt [`block_tables`]: each
/// 4-trit weight byte of row `r` costs one table row — `m` contiguous
/// adds, no decode and no multiply in the inner loop (the bitnet.cpp
/// "TL" lookup idea). Output layout and `inv_s` scaling match
/// [`dot_rows`]; the contract vs the exact core is f32 tolerance (the
/// current table chain happens to agree bitwise — trit weights are exact
/// and both kernels group sums by weight byte), and results are
/// independent of how callers split the row range.
#[allow(clippy::too_many_arguments)]
pub(crate) fn dot_rows_lut(
    packed: &[u32],
    tables: &[f32],
    m: usize,
    k: usize,
    r0: usize,
    rows: usize,
    inv_s: f32,
    out: &mut [f32],
) {
    debug_assert_eq!(k % 4, 0);
    debug_assert_eq!(out.len(), rows * m);
    let bpr = k / 4; // weight bytes (= activation blocks) per row
    debug_assert!(tables.len() >= bpr * 256 * m);
    for rr in 0..rows {
        let byte0 = (r0 + rr) * bpr;
        let orow = &mut out[rr * m..(rr + 1) * m];
        orow.fill(0.0);
        for bj in 0..bpr {
            let b = byte0 + bj;
            let byte = ((packed[b / 4] >> ((b % 4) * 8)) & 0xFF) as usize;
            if byte == 0 {
                continue;
            }
            let trow = &tables[(bj * 256 + byte) * m..(bj * 256 + byte) * m + m];
            for (o, &t) in orow.iter_mut().zip(trow.iter()) {
                *o += t;
            }
        }
        for o in orow.iter_mut() {
            *o *= inv_s;
        }
    }
}

/// Fused packed-ternary GEMM against a row-major `[n_out, k]` weight whose
/// trits live contiguously in `packed` (row `r` starts at trit `r*k`):
/// `y[M, n_out] = x[M, k] @ Wᵀ / scale`.
///
/// This is the decode-free serving matmul (see [`dot_rows`] for the
/// arithmetic): the weight stream is read exactly once per call, so
/// batching `m` sequences amortizes the code decode — the throughput
/// lever continuous batching pulls. Dispatches through
/// [`crate::kernels::ternary`] on the process-default pool
/// (`DQT_THREADS`); callers that own a backend pass their pool to the
/// kernel-layer entry point directly.
pub fn gemm_nt(packed: &[u32], x: &[f32], m: usize, k: usize, n_out: usize, scale: f32) -> Vec<f32> {
    crate::kernels::ternary::gemm_nt(crate::kernels::default_pool(), packed, x, m, k, n_out, scale)
}

/// Fused packed-ternary GEMV: `y[n_out] = W @ x / scale` (single row of
/// [`gemm_nt`] — the batch-1 decode step).
pub fn gemv(packed: &[u32], x: &[f32], k: usize, n_out: usize, scale: f32) -> Vec<f32> {
    gemm_nt(packed, x, 1, k, n_out, scale)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_small() {
        let v = [1.0f32, -1.0, 0.0, 0.0, 1.0, -1.0, -1.0];
        let p = pack(&v).unwrap();
        assert_eq!(unpack(&p, v.len()), v);
    }

    #[test]
    fn roundtrip_unaligned_lengths() {
        for n in [1usize, 3, 4, 5, 15, 16, 17, 31, 32, 33, 1000] {
            let v: Vec<f32> = (0..n).map(|i| ((i % 3) as f32) - 1.0).collect();
            let p = pack(&v).unwrap();
            assert_eq!(unpack(&p, n), v, "n={n}");
            assert_eq!(p.len() * 4, packed_bytes(n));
        }
    }

    #[test]
    fn lut_matches_per_trit_reference() {
        // reference decoder: the seed's per-trit shift/mask loop
        fn unpack_ref(packed: &[u32], n: usize) -> Vec<f32> {
            (0..n)
                .map(|i| {
                    let code = (packed[i / 16] >> ((i % 16) * 2)) & 0b11;
                    match code {
                        0b01 => 1.0,
                        0b10 => -1.0,
                        _ => 0.0,
                    }
                })
                .collect()
        }
        // cover every byte pattern, including the unused 0b11 code
        let words: Vec<u32> = (0..256u32)
            .map(|b| b | (b << 8) | (b << 16) | (b << 24))
            .collect();
        for n in [1usize, 7, 64, 256 * 16] {
            assert_eq!(unpack(&words, n), unpack_ref(&words, n), "n={n}");
        }
    }

    /// Regression for the tail rewrite: every `n % 16` residue class, at
    /// several word counts, both against `pack` round-trips and against
    /// an over-long packed stream (the pre-sliced bulk loop must stop at
    /// exactly `n` even when more words are available).
    #[test]
    fn unpack_covers_every_word_residue() {
        for words in [1usize, 2, 5] {
            for residue in 0..16usize {
                let n = match (words.checked_sub(1), residue) {
                    (Some(w), 0) => w * 16 + 16, // full final word
                    (Some(w), r) => w * 16 + r,
                    _ => unreachable!(),
                };
                let v: Vec<f32> = (0..n).map(|i| ((i * 7 % 3) as f32) - 1.0).collect();
                let p = pack(&v).unwrap();
                assert_eq!(unpack(&p, n), v, "words={words} residue={residue}");
                // extra trailing words must not leak into the output
                let mut long = p.clone();
                long.extend_from_slice(&[0x5555_5555, 0xAAAA_AAAA]);
                assert_eq!(unpack(&long, n), v, "overlong words={words} residue={residue}");
            }
        }
        assert!(unpack(&[0x1234_5678], 0).is_empty());
    }

    #[test]
    fn rejects_non_ternary() {
        assert!(pack(&[2.0]).is_err());
        assert!(pack(&[0.4]).is_ok()); // rounds to 0
    }

    #[test]
    fn compression_ratio_is_16x() {
        let n = 1_000_000;
        assert_eq!(packed_bytes(n) as f64 / (n * 4) as f64, 1.0 / 16.0);
    }

    /// Reference for the fused path: unpack to f32, then dense dot rows.
    fn gemm_ref(packed: &[u32], x: &[f32], m: usize, k: usize, n_out: usize, s: f32) -> Vec<f32> {
        let w: Vec<f32> = unpack(packed, n_out * k).iter().map(|&t| t / s).collect();
        let mut y = vec![0f32; m * n_out];
        for bi in 0..m {
            for r in 0..n_out {
                let mut acc = 0f32;
                for j in 0..k {
                    acc += x[bi * k + j] * w[r * k + j];
                }
                y[bi * n_out + r] = acc;
            }
        }
        y
    }

    #[test]
    fn gemv_matches_unpack_then_dot_small() {
        // k = 5 exercises the unaligned head/tail paths on every row > 0
        let trits: Vec<f32> = (0..3 * 5).map(|i| ((i % 3) as f32) - 1.0).collect();
        let p = pack(&trits).unwrap();
        let x: Vec<f32> = (0..5).map(|i| 0.3 * i as f32 - 0.7).collect();
        let y = gemv(&p, &x, 5, 3, 2.0);
        let r = gemm_ref(&p, &x, 1, 5, 3, 2.0);
        for (a, b) in y.iter().zip(r.iter()) {
            assert!((a - b).abs() < 1e-6, "{y:?} vs {r:?}");
        }
    }

    #[test]
    fn prop_gemm_matches_unpack_then_dot_random_shapes() {
        // random shapes (aligned and not), scales and batch sizes — the
        // fused decode-free path must agree with unpack-then-dot everywhere
        use crate::data::corpus::Rng;
        let mut rng = Rng::new(0xEE7);
        for case in 0..200 {
            let k = 1 + rng.below(70);
            let n_out = 1 + rng.below(40);
            let m = 1 + rng.below(5);
            let s = 0.5 + 40.0 * rng.next_f64() as f32;
            let trits: Vec<f32> = (0..n_out * k).map(|_| rng.below(3) as f32 - 1.0).collect();
            let p = pack(&trits).unwrap();
            let x: Vec<f32> = (0..m * k).map(|_| rng.next_f64() as f32 * 2.0 - 1.0).collect();
            let y = gemm_nt(&p, &x, m, k, n_out, s);
            let r = gemm_ref(&p, &x, m, k, n_out, s);
            for (i, (a, b)) in y.iter().zip(r.iter()).enumerate() {
                let tol = 1e-5f32.max(2e-6 * k as f32 / s);
                assert!(
                    (a - b).abs() < tol,
                    "case {case} (m={m} k={k} n={n_out} s={s}) y[{i}]: {a} vs {b}"
                );
            }
        }
    }

    /// The fast-tier table build agrees with brute-force decode on every
    /// byte: entry (block, byte, batch row) == Σ decode(byte,t)·x[4b+t].
    #[test]
    fn block_tables_match_brute_force() {
        use crate::data::corpus::Rng;
        let mut rng = Rng::new(0x7AB1);
        for &(m, k) in &[(1usize, 8usize), (3, 12), (2, 4)] {
            let x: Vec<f32> = (0..m * k).map(|_| rng.next_f64() as f32 * 2.0 - 1.0).collect();
            let blocks = k / 4;
            let mut tables = vec![0f32; blocks * 256 * m];
            block_tables(&x, m, k, 0, &mut tables);
            for bj in 0..blocks {
                for byte in 0..256usize {
                    for bi in 0..m {
                        let mut want = 0f32;
                        for t in 0..4 {
                            want += CODE_VALUES[(byte >> (2 * t)) & 0b11] * x[bi * k + 4 * bj + t];
                        }
                        let got = tables[(bj * 256 + byte) * m + bi];
                        assert!(
                            (got - want).abs() < 1e-6,
                            "m={m} k={k} block {bj} byte {byte} row {bi}: {got} vs {want}"
                        );
                    }
                }
            }
        }
    }

    /// The LUT dot core matches the exact byte-LUT core to f32 tolerance
    /// on random aligned shapes — including partial row ranges (the way
    /// the kernel layer calls it) and the unused 0b11 code.
    #[test]
    fn prop_dot_rows_lut_matches_exact_core() {
        use crate::data::corpus::Rng;
        let mut rng = Rng::new(0x1EE7);
        for case in 0..60 {
            let k = 4 * (1 + rng.below(40)); // byte-aligned rows only
            let n_out = 1 + rng.below(30);
            let m = 1 + rng.below(5);
            let inv_s = 1.0 / (0.5 + 10.0 * rng.next_f64() as f32);
            let trits: Vec<f32> = (0..n_out * k).map(|_| rng.below(3) as f32 - 1.0).collect();
            let p = pack(&trits).unwrap();
            let x: Vec<f32> = (0..m * k).map(|_| rng.next_f64() as f32 * 2.0 - 1.0).collect();
            let mut tables = vec![0f32; (k / 4) * 256 * m];
            block_tables(&x, m, k, 0, &mut tables);
            let r0 = rng.below(n_out);
            let rows = n_out - r0;
            let mut exact = vec![0f32; rows * m];
            dot_rows(&p, &x, m, k, r0, rows, inv_s, &mut exact);
            let mut lut = vec![0f32; rows * m];
            dot_rows_lut(&p, &tables, m, k, r0, rows, inv_s, &mut lut);
            for (i, (a, b)) in lut.iter().zip(exact.iter()).enumerate() {
                let tol = 1e-5 + 1e-6 * k as f32;
                assert!(
                    (a - b).abs() <= tol * (1.0 + b.abs()),
                    "case {case} (m={m} k={k} n={n_out} r0={r0}) [{i}]: lut {a} vs exact {b}"
                );
            }
        }
        // a stream of unused 0b11 codes decodes to zero through the tables
        let words = vec![0xFFFF_FFFFu32; 2];
        let x = vec![1.0f32; 8];
        let mut tables = vec![0f32; 2 * 256];
        block_tables(&x, 1, 8, 0, &mut tables);
        let mut y = vec![1f32; 4];
        dot_rows_lut(&words, &tables, 1, 8, 0, 4, 1.0, &mut y);
        assert_eq!(y, vec![0.0; 4]);
    }

    #[test]
    fn gemm_handles_unused_code_like_unpack() {
        // a stream full of 0b11 codes decodes to zeros in both paths
        let words = vec![0xFFFF_FFFFu32; 2];
        let x = vec![1.0f32; 8];
        assert_eq!(gemv(&words, &x, 8, 4, 1.0), vec![0.0; 4]);
    }

    #[test]
    fn gemm_batched_equals_per_row_gemv() {
        let trits: Vec<f32> = (0..6 * 16).map(|i| ((i * 7 % 3) as f32) - 1.0).collect();
        let p = pack(&trits).unwrap();
        let x: Vec<f32> = (0..3 * 16).map(|i| (i as f32 - 20.0) * 0.11).collect();
        let batched = gemm_nt(&p, &x, 3, 16, 6, 4.0);
        for bi in 0..3 {
            let solo = gemv(&p, &x[bi * 16..(bi + 1) * 16], 16, 6, 4.0);
            assert_eq!(&batched[bi * 6..(bi + 1) * 6], &solo[..], "row {bi}");
        }
    }
}
