//! 2-bit packing of ternary {-1, 0, +1} weights — the deployment format.
//!
//! Encoding per trit: `0b00` = 0, `0b01` = +1, `0b10` = -1 (`0b11` unused).
//! 16 trits per `u32`, little-endian within the word. A 1B-parameter ternary
//! model packs to 0.25 GB vs 4 GB in FP32 — the 16× reduction the paper's
//! introduction cites.

/// Pack ternary values (given as f32 in {-1.0, 0.0, +1.0}) into 2-bit codes.
///
/// Values are snapped with `round()`; anything outside {-1,0,1} after
/// rounding is an error (the caller must pass grid values).
pub fn pack(values: &[f32]) -> Result<Vec<u32>, String> {
    let mut out = vec![0u32; values.len().div_ceil(16)];
    for (i, &v) in values.iter().enumerate() {
        let k = v.round() as i32;
        let code: u32 = match k {
            0 => 0b00,
            1 => 0b01,
            -1 => 0b10,
            _ => return Err(format!("value {v} at {i} is not ternary")),
        };
        out[i / 16] |= code << ((i % 16) * 2);
    }
    Ok(out)
}

/// Unpack `n` ternary values from 2-bit codes.
pub fn unpack(packed: &[u32], n: usize) -> Vec<f32> {
    (0..n)
        .map(|i| {
            let code = (packed[i / 16] >> ((i % 16) * 2)) & 0b11;
            match code {
                0b01 => 1.0,
                0b10 => -1.0,
                _ => 0.0,
            }
        })
        .collect()
}

/// Packed size in bytes for `n` ternary weights.
pub fn packed_bytes(n: usize) -> usize {
    n.div_ceil(16) * 4
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_small() {
        let v = [1.0f32, -1.0, 0.0, 0.0, 1.0, -1.0, -1.0];
        let p = pack(&v).unwrap();
        assert_eq!(unpack(&p, v.len()), v);
    }

    #[test]
    fn roundtrip_unaligned_lengths() {
        for n in [1usize, 15, 16, 17, 31, 32, 33, 1000] {
            let v: Vec<f32> = (0..n).map(|i| ((i % 3) as f32) - 1.0).collect();
            let p = pack(&v).unwrap();
            assert_eq!(unpack(&p, n), v, "n={n}");
            assert_eq!(p.len() * 4, packed_bytes(n));
        }
    }

    #[test]
    fn rejects_non_ternary() {
        assert!(pack(&[2.0]).is_err());
        assert!(pack(&[0.4]).is_ok()); // rounds to 0
    }

    #[test]
    fn compression_ratio_is_16x() {
        let n = 1_000_000;
        assert_eq!(packed_bytes(n) as f64 / (n * 4) as f64, 1.0 / 16.0);
    }
}
