//! 2-bit packing of ternary {-1, 0, +1} weights — the deployment format.
//!
//! Encoding per trit: `0b00` = 0, `0b01` = +1, `0b10` = -1 (`0b11` unused,
//! decoded as 0). 16 trits per `u32`, little-endian within the word. A
//! 1B-parameter ternary model packs to 0.25 GB vs 4 GB in FP32 — the 16×
//! reduction the paper's introduction cites.
//!
//! The hot paths are vectorized: `pack` accumulates a whole word before
//! storing (no per-trit index arithmetic), and `unpack` expands four trits
//! at a time through a 256-entry byte→`[f32; 4]` lookup table.

use std::sync::OnceLock;

/// Decoded value of each 2-bit code (`0b11` falls back to 0, matching the
/// historical per-trit decoder).
const CODE_VALUES: [f32; 4] = [0.0, 1.0, -1.0, 0.0];

/// byte → the four trit values it encodes (LSB-first pairs).
fn byte_lut() -> &'static [[f32; 4]; 256] {
    static LUT: OnceLock<[[f32; 4]; 256]> = OnceLock::new();
    LUT.get_or_init(|| {
        let mut t = [[0.0f32; 4]; 256];
        for (b, row) in t.iter_mut().enumerate() {
            for (j, slot) in row.iter_mut().enumerate() {
                *slot = CODE_VALUES[(b >> (2 * j)) & 0b11];
            }
        }
        t
    })
}

/// Pack ternary values (given as f32 in {-1.0, 0.0, +1.0}) into 2-bit codes.
///
/// Values are snapped with `round()`; anything outside {-1,0,1} after
/// rounding is an error (the caller must pass grid values).
pub fn pack(values: &[f32]) -> Result<Vec<u32>, String> {
    let mut out = Vec::with_capacity(values.len().div_ceil(16));
    let mut word = 0u32;
    let mut shift = 0u32;
    for (i, &v) in values.iter().enumerate() {
        let code: u32 = match v.round() as i32 {
            0 => 0b00,
            1 => 0b01,
            -1 => 0b10,
            _ => return Err(format!("value {v} at {i} is not ternary")),
        };
        word |= code << shift;
        shift += 2;
        if shift == 32 {
            out.push(word);
            word = 0;
            shift = 0;
        }
    }
    if shift > 0 {
        out.push(word);
    }
    Ok(out)
}

/// Unpack `n` ternary values from 2-bit codes (LUT-based, 4 trits/step).
/// Panics if `packed` holds fewer than `n` trits (like the seed's
/// index-out-of-bounds, but with a message).
pub fn unpack(packed: &[u32], n: usize) -> Vec<f32> {
    assert!(
        packed.len() * 16 >= n,
        "packed ternary stream holds {} trits, {n} requested",
        packed.len() * 16
    );
    let lut = byte_lut();
    let mut out = Vec::with_capacity(n);
    for &word in packed {
        if out.len() >= n {
            break;
        }
        for b in word.to_le_bytes() {
            let vals = &lut[b as usize];
            let remaining = n - out.len();
            if remaining >= 4 {
                out.extend_from_slice(vals);
            } else {
                out.extend_from_slice(&vals[..remaining]);
                break;
            }
        }
    }
    out
}

/// Packed size in bytes for `n` ternary weights.
pub fn packed_bytes(n: usize) -> usize {
    n.div_ceil(16) * 4
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_small() {
        let v = [1.0f32, -1.0, 0.0, 0.0, 1.0, -1.0, -1.0];
        let p = pack(&v).unwrap();
        assert_eq!(unpack(&p, v.len()), v);
    }

    #[test]
    fn roundtrip_unaligned_lengths() {
        for n in [1usize, 3, 4, 5, 15, 16, 17, 31, 32, 33, 1000] {
            let v: Vec<f32> = (0..n).map(|i| ((i % 3) as f32) - 1.0).collect();
            let p = pack(&v).unwrap();
            assert_eq!(unpack(&p, n), v, "n={n}");
            assert_eq!(p.len() * 4, packed_bytes(n));
        }
    }

    #[test]
    fn lut_matches_per_trit_reference() {
        // reference decoder: the seed's per-trit shift/mask loop
        fn unpack_ref(packed: &[u32], n: usize) -> Vec<f32> {
            (0..n)
                .map(|i| {
                    let code = (packed[i / 16] >> ((i % 16) * 2)) & 0b11;
                    match code {
                        0b01 => 1.0,
                        0b10 => -1.0,
                        _ => 0.0,
                    }
                })
                .collect()
        }
        // cover every byte pattern, including the unused 0b11 code
        let words: Vec<u32> = (0..256u32)
            .map(|b| b | (b << 8) | (b << 16) | (b << 24))
            .collect();
        for n in [1usize, 7, 64, 256 * 16] {
            assert_eq!(unpack(&words, n), unpack_ref(&words, n), "n={n}");
        }
    }

    #[test]
    fn rejects_non_ternary() {
        assert!(pack(&[2.0]).is_err());
        assert!(pack(&[0.4]).is_ok()); // rounds to 0
    }

    #[test]
    fn compression_ratio_is_16x() {
        let n = 1_000_000;
        assert_eq!(packed_bytes(n) as f64 / (n * 4) as f64, 1.0 / 16.0);
    }
}
