//! Gradient wire codec: the paper's stochastic-rounding trick applied to
//! the distributed gradient exchange.
//!
//! `dist/` ships one parameter-sized gradient partial per worker link per
//! step; as f32 that is the dominant per-step network cost. This module
//! stochastically rounds each f32 gradient buffer onto an int8 or ternary
//! grid (per-tensor absmax scale) before it hits the wire, and keeps a
//! per-rank **error-feedback residual** so the quantization error of step
//! `k` is carried into step `k+1` instead of lost:
//!
//! ```text
//! x_k      = g_k + r_{k-1}
//! sent_k   = SR(x_k)           (on the grid, packed to 8 / 2 bits)
//! r_k      = x_k - sent_k
//! ```
//!
//! SR alone keeps each step unbiased (`E[sent] = x`, [`super::sr`]); the
//! residual bounds the *accumulated* error of a buffer by one grid step
//! instead of a √K random walk — pinned by the tests below and by the
//! int8 convergence contract in `rust/tests/dist.rs`. The rounding uses
//! the same counter-hash PRNG as the weight updates, seeded per
//! `(step, lane, entry)`, so every rank's wire stream is deterministic.
//!
//! The packed bytes use the codec registry ([`super::codec`]) exactly as
//! the weight resync does: [`Format::IntN`]`(8)` (1 byte/value, ~4× under
//! f32) or [`Format::Ternary2bit`] (2 bits/value, ~16×). The residual is
//! one f32 copy of the gradient set per rank — `memory::dist_estimate`
//! reports that cost honestly.

use super::codec::Format;
use super::sr::{hash_u32, sr_scalar};

/// One gradient buffer quantized for the wire: grid codes in the set's
/// [`Format`] plus the per-tensor absmax scale that dequantizes them.
/// The format itself rides once per frame (`dist::wire::Frame::
/// PackedGradSet`), not per entry.
#[derive(Clone, Debug, PartialEq)]
pub struct PackedGrad {
    /// grid scale: dequantized value = code / scale
    pub scale: f32,
    /// number of f32 values this buffer decodes to
    pub numel: usize,
    /// packed grid codes, `format.packed_bytes(numel)` long
    pub bytes: Vec<u8>,
}

impl PackedGrad {
    /// Rebuild from untrusted wire fields, re-checking the codec's size
    /// invariant (the same hardening `PackedTensor::from_bytes` applies).
    pub fn from_wire(
        format: Format,
        scale: f32,
        numel: usize,
        bytes: Vec<u8>,
    ) -> Result<PackedGrad, String> {
        let want = format.packed_bytes(numel);
        if bytes.len() != want {
            return Err(format!(
                "packed grad of {numel} values is {} bytes, {} expects {want}",
                bytes.len(),
                format.tag()
            ));
        }
        if !scale.is_finite() || scale <= 0.0 {
            return Err(format!("packed grad scale {scale} is not a positive finite"));
        }
        Ok(PackedGrad { scale, numel, bytes })
    }

    /// Dequantize back to f32 values.
    pub fn decode(&self, format: Format) -> Result<Vec<f32>, String> {
        format.decode(&self.bytes, self.numel, Some(self.scale))
    }
}

/// Per-rank gradient wire codec: the quantization format plus the
/// error-feedback residual state for every buffer this rank encodes.
/// A worker encodes its uplink partial through one codec; rank 0 encodes
/// the reduced broadcast through its own — each direction carries its own
/// residuals.
pub struct GradCodec {
    format: Format,
    error_feedback: bool,
    /// residual layout mirrors the gradient set (None for absent entries);
    /// sized lazily on the first encode, then held fixed
    residuals: Vec<Option<Vec<f32>>>,
}

impl GradCodec {
    /// A codec for `format` with error feedback on (the production
    /// configuration). Only grid formats can quantize a gradient wire.
    pub fn new(format: Format) -> Result<GradCodec, String> {
        Self::build(format, true)
    }

    /// Error feedback disabled — SR-only. Exists so tests can demonstrate
    /// the residual is load-bearing; never used by the training path.
    pub fn without_error_feedback(format: Format) -> Result<GradCodec, String> {
        Self::build(format, false)
    }

    fn build(format: Format, error_feedback: bool) -> Result<GradCodec, String> {
        if !format.is_grid_format() {
            return Err(format!(
                "gradient wire codec needs a grid format, not {}",
                format.tag()
            ));
        }
        Ok(GradCodec {
            format,
            error_feedback,
            residuals: Vec::new(),
        })
    }

    pub fn format(&self) -> Format {
        self.format
    }

    /// Bytes of residual state this codec currently holds — one f32 per
    /// gradient value (the memory cost `dist_estimate` reports).
    pub fn residual_bytes(&self) -> u64 {
        self.residuals
            .iter()
            .flatten()
            .map(|r| r.len() as u64 * 4)
            .sum()
    }

    /// The deterministic SR seed for one `(step, lane, entry)` site.
    /// `lane` separates the per-rank uplink streams from rank 0's
    /// broadcast stream so no two wire encodings share a random stream.
    pub fn entry_seed(step: u64, lane: u32, entry: usize) -> u32 {
        let s = hash_u32(step as u32, hash_u32((step >> 32) as u32, 0x6AD5_37C1));
        hash_u32(entry as u32, hash_u32(lane, s))
    }

    /// Quantize one gradient set for the wire. Each present buffer gets a
    /// per-tensor absmax scale mapping its largest `|g + r|` onto the grid
    /// edge, is stochastically rounded, and leaves its rounding error in
    /// this codec's residual for the next step.
    pub fn encode_set(
        &mut self,
        step: u64,
        lane: u32,
        grads: &[Option<Vec<f32>>],
    ) -> Result<Vec<Option<PackedGrad>>, String> {
        if self.residuals.is_empty() {
            self.residuals = grads
                .iter()
                .map(|g| g.as_ref().map(|v| vec![0.0f32; v.len()]))
                .collect();
        }
        if self.residuals.len() != grads.len() {
            return Err(format!(
                "gradient layout changed mid-run: {} entries, residuals hold {}",
                grads.len(),
                self.residuals.len()
            ));
        }
        let (qn, qp) = self.format.grid_range();
        let (qn, qp) = (qn as f32, qp as f32);
        let mut out = Vec::with_capacity(grads.len());
        for (i, g) in grads.iter().enumerate() {
            let Some(g) = g else {
                out.push(None);
                continue;
            };
            let r = self.residuals[i].as_mut().ok_or_else(|| {
                format!("gradient entry {i} appeared after the layout was fixed")
            })?;
            if r.len() != g.len() {
                return Err(format!(
                    "gradient entry {i} is {} values, residual holds {}",
                    g.len(),
                    r.len()
                ));
            }
            let mut absmax = 0.0f32;
            for (x, rr) in g.iter().zip(r.iter()) {
                absmax = absmax.max((x + rr).abs());
            }
            // an all-zero buffer encodes as zeros under any scale
            let s = if absmax > 0.0 { qp / absmax } else { 1.0 };
            let seed = Self::entry_seed(step, lane, i);
            let mut q = Vec::with_capacity(g.len());
            for (j, (x, rr)) in g.iter().zip(r.iter_mut()).enumerate() {
                let carried = x + *rr;
                let sent = sr_scalar(carried, j as u32, seed, qn, qp, s);
                *rr = if self.error_feedback { carried - sent } else { 0.0 };
                q.push(sent);
            }
            let bytes = self.format.encode(&q, Some(s))?;
            out.push(Some(PackedGrad {
                scale: s,
                numel: g.len(),
                bytes,
            }));
        }
        Ok(out)
    }

    /// Dequantize a received set (no residual state involved — decoding
    /// is stateless and identical on every rank).
    pub fn decode_set(
        format: Format,
        entries: &[Option<PackedGrad>],
    ) -> Result<Vec<Option<Vec<f32>>>, String> {
        entries
            .iter()
            .map(|e| e.as_ref().map(|p| p.decode(format)).transpose())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn grads(vals: &[f32]) -> Vec<Option<Vec<f32>>> {
        vec![Some(vals.to_vec()), None]
    }

    #[test]
    fn only_grid_formats_are_accepted() {
        assert!(GradCodec::new(Format::IntN(8)).is_ok());
        assert!(GradCodec::new(Format::Ternary2bit).is_ok());
        assert!(GradCodec::new(Format::F32).is_err());
        assert!(GradCodec::new(Format::Bf16).is_err());
    }

    #[test]
    fn encode_decode_roundtrip_stays_on_grid_and_near_input() {
        let g: Vec<f32> = (0..257).map(|i| ((i as f32) * 0.37).sin() * 1e-2).collect();
        let mut codec = GradCodec::new(Format::IntN(8)).unwrap();
        let packed = codec.encode_set(3, 1, &grads(&g)).unwrap();
        assert!(packed[1].is_none());
        let p = packed[0].as_ref().unwrap();
        assert_eq!(p.numel, g.len());
        assert_eq!(p.bytes.len(), Format::IntN(8).packed_bytes(g.len()));
        let back = GradCodec::decode_set(Format::IntN(8), &packed).unwrap();
        let back = back[0].as_ref().unwrap();
        // every decoded value is on the grid and within one grid step of
        // the input (SR moves to an adjacent grid point)
        let step = 1.0 / p.scale;
        for (a, b) in g.iter().zip(back.iter()) {
            let k = b * p.scale;
            assert!((k - k.round()).abs() < 1e-3, "{b} is off-grid");
            assert!((a - b).abs() <= step * 1.001, "{a} vs {b} (step {step})");
        }
    }

    #[test]
    fn zero_buffer_encodes_to_zero() {
        let mut codec = GradCodec::new(Format::Ternary2bit).unwrap();
        let packed = codec.encode_set(0, 0, &grads(&[0.0; 64])).unwrap();
        let back = GradCodec::decode_set(Format::Ternary2bit, &packed).unwrap();
        assert!(back[0].as_ref().unwrap().iter().all(|&v| v == 0.0));
        assert_eq!(codec.residual_bytes(), 64 * 4);
    }

    /// The error-feedback contract (satellite): over K steps of a
    /// constant gradient, the residual-carried quantized *sum* stays
    /// within one grid step of the f32 sum — while the same stream
    /// without EF random-walks measurably further. Deterministic: the
    /// counter-hash PRNG makes both runs exact functions of the seeds.
    /// Verified independently by a python simulation of the same PRNG
    /// (see CHANGES.md PR 9).
    #[test]
    fn error_feedback_bounds_the_k_step_sum_and_disabling_it_degrades() {
        let g: Vec<f32> = (0..64).map(|i| 0.013 + (i as f32) * 1e-4).collect();
        let k_steps = 64u64;

        let sum_err = |ef: bool| -> f32 {
            let mut codec = if ef {
                GradCodec::new(Format::Ternary2bit).unwrap()
            } else {
                GradCodec::without_error_feedback(Format::Ternary2bit).unwrap()
            };
            let mut sum = vec![0.0f32; g.len()];
            let mut scale = 0.0f32;
            for step in 0..k_steps {
                let packed = codec.encode_set(step, 7, &grads(&g)).unwrap();
                scale = packed[0].as_ref().unwrap().scale;
                let back = GradCodec::decode_set(Format::Ternary2bit, &packed).unwrap();
                for (s, v) in sum.iter_mut().zip(back[0].as_ref().unwrap()) {
                    *s += v;
                }
            }
            let grid_step = 1.0 / scale;
            let max_err = sum
                .iter()
                .zip(g.iter())
                .map(|(s, gv)| (s - gv * k_steps as f32).abs())
                .fold(0.0f32, f32::max);
            max_err / grid_step // error in units of the grid step
        };

        let ef_err = sum_err(true);
        let raw_err = sum_err(false);
        // with EF the accumulated error is at most ~one grid step…
        assert!(ef_err <= 1.001, "EF sum error {ef_err} grid steps");
        // …without it, the K-step random walk is measurably worse — the
        // test is non-vacuous
        assert!(
            raw_err > 2.0 * ef_err.max(0.5),
            "no-EF error {raw_err} should exceed EF error {ef_err}"
        );
    }

    /// SR stays unbiased through the codec: the mean of many independent
    /// encodings of one buffer converges on the buffer itself.
    #[test]
    fn single_shot_encoding_is_unbiased() {
        // varied values: the absmax element lands on the grid exactly,
        // every other one genuinely rounds stochastically
        let g: Vec<f32> = (0..16).map(|i| 0.001 + i as f32 * 3e-4).collect();
        let mut mean = vec![0.0f64; g.len()];
        let n = 4000u64;
        for step in 0..n {
            let mut codec = GradCodec::without_error_feedback(Format::IntN(8)).unwrap();
            let packed = codec.encode_set(step, 2, &grads(&g)).unwrap();
            let back = GradCodec::decode_set(Format::IntN(8), &packed).unwrap();
            for (m, v) in mean.iter_mut().zip(back[0].as_ref().unwrap()) {
                *m += *v as f64 / n as f64;
            }
        }
        for (m, gv) in mean.iter().zip(g.iter()) {
            assert!((m - *gv as f64).abs() < 2e-5, "mean {m} vs {gv}");
        }
    }

    #[test]
    fn from_wire_rejects_size_and_scale_lies() {
        let ok = Format::IntN(8).packed_bytes(10);
        assert!(PackedGrad::from_wire(Format::IntN(8), 4.0, 10, vec![0; ok]).is_ok());
        assert!(PackedGrad::from_wire(Format::IntN(8), 4.0, 10, vec![0; ok - 1]).is_err());
        assert!(PackedGrad::from_wire(Format::Ternary2bit, 4.0, 10, vec![0; ok]).is_err());
        assert!(PackedGrad::from_wire(Format::IntN(8), 0.0, 10, vec![0; ok]).is_err());
        assert!(
            PackedGrad::from_wire(Format::IntN(8), f32::NAN, 10, vec![0; ok]).is_err()
        );
    }

    #[test]
    fn layout_changes_are_rejected() {
        let mut codec = GradCodec::new(Format::IntN(8)).unwrap();
        codec.encode_set(0, 0, &grads(&[1.0, 2.0])).unwrap();
        // entry count change
        assert!(codec.encode_set(1, 0, &[Some(vec![1.0])]).is_err());
        // entry length change
        assert!(codec.encode_set(1, 0, &grads(&[1.0])).is_err());
        // present where None was fixed
        assert!(codec
            .encode_set(1, 0, &[Some(vec![1.0, 2.0]), Some(vec![3.0])])
            .is_err());
    }

    #[test]
    fn seeds_differ_across_steps_lanes_and_entries() {
        let base = GradCodec::entry_seed(5, 1, 0);
        assert_ne!(base, GradCodec::entry_seed(6, 1, 0));
        assert_ne!(base, GradCodec::entry_seed(5, 2, 0));
        assert_ne!(base, GradCodec::entry_seed(5, 1, 1));
        assert_eq!(base, GradCodec::entry_seed(5, 1, 0));
    }
}
