//! Unified codec registry: one place that knows every storage format.
//!
//! Before this module existed, the per-format knowledge (wire tag, packed
//! size, encode/decode loops, the `bits == 1.58` ternary sentinel) was
//! scattered across five free-function modules plus a private enum in
//! `train::checkpoint` with triplicated match-dispatch. Everything now
//! routes through two types:
//!
//! * [`Format`] — the closed set of storage formats (`F32`, `Bf16`,
//!   `Fp8E4m3`, `Ternary2bit`, `IntN`). [`Format::from_bits`] is the *only*
//!   place that interprets the paper's fractional bit-width sentinel
//!   (`1.58` ⇒ ternary); [`Format::from_tag`] is the only wire-tag parser.
//! * [`Codec`] — the behavior behind a format: `encode`/`decode` between
//!   f32 values and packed bytes, `packed_bytes` for the memory model, and
//!   the wire `tag`. One implementation per format, reachable via
//!   [`Format::codec`].
//!
//! [`PackedTensor`] bundles `format + shape + scale + bytes` into the
//! canonical host representation of a grid weight: `train::checkpoint`
//! writes its payload, `runtime::State`'s packed-grid mode keeps it
//! resident (realizing the 16× ternary reduction of paper §1 in host RSS,
//! not just on disk), and the memory model reads sizes from it.
//!
//! Grid codecs (`Ternary2bit`, `IntN`) store integer grid indices
//! `k = w·s` and need the AbsMean scale `s` to map back to f32 values;
//! dense codecs (`F32`, `Bf16`, `Fp8E4m3`) are scale-free.

use super::{bf16, fp8, intn, ternary};

/// The paper's ternary bit-width sentinel (log2(3) ≈ 1.58 information
/// bound; stored at a practical 2 bits/weight).
pub const TERNARY_BITS: f64 = 1.58;

/// A storage format. `Copy`, order-free, and the key of the codec registry.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Format {
    /// Raw little-endian f32 (4 bytes/value).
    F32,
    /// BF16 round-to-nearest-even (2 bytes/value).
    Bf16,
    /// OCP FP8 E4M3, saturating (1 byte/value).
    Fp8E4m3,
    /// 2-bit packed ternary grid {-1, 0, +1} (16 values per u32 word).
    Ternary2bit,
    /// Bit-packed signed INTn grid, n ∈ 2..=8.
    IntN(u32),
}

impl Format {
    /// The single constructor that interprets a grid bit-width. `1.58`
    /// (within 1e-9) selects the ternary format; anything else truncates
    /// to an integer width (unvalidated, like the seed code — widths
    /// outside `2..=8` fail loudly at [`Format::codec`] lookup, while
    /// [`Format::grid_range`] and [`Format::bits_per_weight`] stay
    /// arithmetic for any width).
    ///
    /// Every former call site of the `(bits - 1.58).abs() < 1e-9` sentinel
    /// (`quant::qrange`, `checkpoint::Codec::for_entry`,
    /// `quant::bits_per_weight`) now routes through here.
    pub fn from_bits(bits: f64) -> Format {
        if (bits - TERNARY_BITS).abs() < 1e-9 {
            Format::Ternary2bit
        } else {
            Format::IntN(bits as u32)
        }
    }

    /// Format for one manifest entry: grid params follow the variant's
    /// bit width, everything else uses the caller's dense format.
    pub fn for_entry(is_grid: bool, bits: f64, dense: Format) -> Format {
        if is_grid {
            Format::from_bits(bits)
        } else {
            dense
        }
    }

    /// Grid formats store integer indices and need an AbsMean scale.
    pub fn is_grid_format(self) -> bool {
        matches!(self, Format::Ternary2bit | Format::IntN(_))
    }

    /// Integer grid range `[q_min, q_max]` (paper Eq. Qn/Qp, §3.2).
    /// Continuous formats have no grid and return the full real line.
    pub fn grid_range(self) -> (f64, f64) {
        match self {
            Format::Ternary2bit => (-1.0, 1.0),
            Format::IntN(n) => {
                let half = 2f64.powi(n as i32 - 1);
                (-half, half - 1.0)
            }
            _ => (f64::NEG_INFINITY, f64::INFINITY),
        }
    }

    /// The codec behind this format (the registry lookup). Panics for
    /// INTn widths outside `2..=8` — the same loud failure the packers
    /// themselves assert.
    pub fn codec(self) -> &'static dyn Codec {
        match self {
            Format::F32 => &F32_CODEC,
            Format::Bf16 => &BF16_CODEC,
            Format::Fp8E4m3 => &FP8_E4M3_CODEC,
            Format::Ternary2bit => &TERNARY_CODEC,
            Format::IntN(b) => {
                assert!((2..=8).contains(&b), "unsupported INT{b} codec");
                &INTN_CODECS[(b - 2) as usize]
            }
        }
    }

    /// Wire tag (the `codec` field of a `.dqt` header entry).
    pub fn tag(self) -> String {
        self.codec().tag()
    }

    /// Inverse of [`Format::tag`] — the only wire-tag parser.
    pub fn from_tag(s: &str) -> Result<Format, String> {
        Ok(match s {
            "f32" => Format::F32,
            "bf16" => Format::Bf16,
            "fp8_e4m3" => Format::Fp8E4m3,
            "ternary_2bit" => Format::Ternary2bit,
            _ => {
                let b: u32 = s
                    .strip_prefix("int")
                    .and_then(|x| x.parse().ok())
                    .filter(|b| (2..=8).contains(b))
                    .ok_or_else(|| format!("unknown codec {s:?}"))?;
                Format::IntN(b)
            }
        })
    }

    /// Packed size in bytes of `n` values.
    pub fn packed_bytes(self, n: usize) -> usize {
        self.codec().packed_bytes(n)
    }

    /// Storage cost in bits per weight (the memory model's unit). For
    /// INTn this is plain arithmetic (`n`), valid even for widths the
    /// packers don't support — the seed memory model behaved the same.
    pub fn bits_per_weight(self) -> f64 {
        match self {
            Format::IntN(b) => b as f64,
            _ => self.codec().bits_per_weight(),
        }
    }

    /// Encode f32 values to packed bytes (`scale` required for grid
    /// formats).
    pub fn encode(self, vals: &[f32], scale: Option<f32>) -> Result<Vec<u8>, String> {
        self.codec().encode(vals, scale)
    }

    /// Decode `n` values from packed bytes (`scale` required for grid
    /// formats). Rejects byte slices whose length does not match
    /// `packed_bytes(n)`.
    pub fn decode(self, bytes: &[u8], n: usize, scale: Option<f32>) -> Result<Vec<f32>, String> {
        self.codec().decode(bytes, n, scale)
    }
}

/// Behavior of one storage format. Implementations are registered as
/// statics and reached through [`Format::codec`]; consumers should not
/// dispatch on [`Format`] variants themselves.
pub trait Codec: Sync {
    /// Wire tag written into checkpoint headers.
    fn tag(&self) -> String;
    /// Storage cost in bits per weight.
    fn bits_per_weight(&self) -> f64;
    /// Packed size in bytes of `n` values.
    fn packed_bytes(&self, n: usize) -> usize;
    /// f32 values → packed bytes.
    fn encode(&self, vals: &[f32], scale: Option<f32>) -> Result<Vec<u8>, String>;
    /// packed bytes → f32 values.
    fn decode(&self, bytes: &[u8], n: usize, scale: Option<f32>) -> Result<Vec<f32>, String>;
}

fn check_len(tag: &str, got: usize, want: usize) -> Result<(), String> {
    if got != want {
        return Err(format!("{tag} payload is {got} bytes, expected {want}"));
    }
    Ok(())
}

fn grid_scale(tag: &str, scale: Option<f32>) -> Result<f32, String> {
    scale.ok_or_else(|| format!("{tag} codec needs scale"))
}

struct F32Codec;

impl Codec for F32Codec {
    fn tag(&self) -> String {
        "f32".into()
    }
    fn bits_per_weight(&self) -> f64 {
        32.0
    }
    fn packed_bytes(&self, n: usize) -> usize {
        n * 4
    }
    fn encode(&self, vals: &[f32], _scale: Option<f32>) -> Result<Vec<u8>, String> {
        Ok(vals.iter().flat_map(|v| v.to_le_bytes()).collect())
    }
    fn decode(&self, bytes: &[u8], n: usize, _scale: Option<f32>) -> Result<Vec<f32>, String> {
        check_len("f32", bytes.len(), self.packed_bytes(n))?;
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }
}

struct Bf16Codec;

impl Codec for Bf16Codec {
    fn tag(&self) -> String {
        "bf16".into()
    }
    fn bits_per_weight(&self) -> f64 {
        16.0
    }
    fn packed_bytes(&self, n: usize) -> usize {
        n * 2
    }
    fn encode(&self, vals: &[f32], _scale: Option<f32>) -> Result<Vec<u8>, String> {
        Ok(vals
            .iter()
            .flat_map(|&v| bf16::encode(v).to_le_bytes())
            .collect())
    }
    fn decode(&self, bytes: &[u8], n: usize, _scale: Option<f32>) -> Result<Vec<f32>, String> {
        check_len("bf16", bytes.len(), self.packed_bytes(n))?;
        Ok(bytes
            .chunks_exact(2)
            .map(|c| bf16::decode(u16::from_le_bytes(c.try_into().unwrap())))
            .collect())
    }
}

struct Fp8E4m3Codec;

impl Codec for Fp8E4m3Codec {
    fn tag(&self) -> String {
        "fp8_e4m3".into()
    }
    fn bits_per_weight(&self) -> f64 {
        8.0
    }
    fn packed_bytes(&self, n: usize) -> usize {
        n
    }
    fn encode(&self, vals: &[f32], _scale: Option<f32>) -> Result<Vec<u8>, String> {
        Ok(vals
            .iter()
            .map(|&v| fp8::encode(v, fp8::Format::E4M3))
            .collect())
    }
    fn decode(&self, bytes: &[u8], n: usize, _scale: Option<f32>) -> Result<Vec<f32>, String> {
        check_len("fp8_e4m3", bytes.len(), self.packed_bytes(n))?;
        Ok(bytes
            .iter()
            .map(|&b| fp8::decode(b, fp8::Format::E4M3))
            .collect())
    }
}

struct TernaryCodec;

impl Codec for TernaryCodec {
    fn tag(&self) -> String {
        "ternary_2bit".into()
    }
    fn bits_per_weight(&self) -> f64 {
        2.0 // practical 2-bit packing (1.58 is the information bound)
    }
    fn packed_bytes(&self, n: usize) -> usize {
        ternary::packed_bytes(n)
    }
    fn encode(&self, vals: &[f32], scale: Option<f32>) -> Result<Vec<u8>, String> {
        let s = grid_scale("ternary", scale)?;
        let k: Vec<f32> = vals.iter().map(|&v| (v * s).round()).collect();
        Ok(ternary::pack(&k)?
            .iter()
            .flat_map(|w| w.to_le_bytes())
            .collect())
    }
    fn decode(&self, bytes: &[u8], n: usize, scale: Option<f32>) -> Result<Vec<f32>, String> {
        let s = grid_scale("ternary", scale)?;
        check_len("ternary_2bit", bytes.len(), self.packed_bytes(n))?;
        let words: Vec<u32> = bytes
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        Ok(ternary::unpack(&words, n).iter().map(|&k| k / s).collect())
    }
}

struct IntNCodec {
    bits: u32,
}

impl Codec for IntNCodec {
    fn tag(&self) -> String {
        format!("int{}", self.bits)
    }
    fn bits_per_weight(&self) -> f64 {
        self.bits as f64
    }
    fn packed_bytes(&self, n: usize) -> usize {
        intn::packed_bytes(n, self.bits)
    }
    fn encode(&self, vals: &[f32], scale: Option<f32>) -> Result<Vec<u8>, String> {
        let s = grid_scale("intn", scale)?;
        intn::pack_grid(vals, s, self.bits)
    }
    fn decode(&self, bytes: &[u8], n: usize, scale: Option<f32>) -> Result<Vec<f32>, String> {
        let s = grid_scale("intn", scale)?;
        check_len("intn", bytes.len(), self.packed_bytes(n))?;
        Ok(intn::unpack_grid(bytes, n, s, self.bits))
    }
}

static F32_CODEC: F32Codec = F32Codec;
static BF16_CODEC: Bf16Codec = Bf16Codec;
static FP8_E4M3_CODEC: Fp8E4m3Codec = Fp8E4m3Codec;
static TERNARY_CODEC: TernaryCodec = TernaryCodec;
static INTN_CODECS: [IntNCodec; 7] = [
    IntNCodec { bits: 2 },
    IntNCodec { bits: 3 },
    IntNCodec { bits: 4 },
    IntNCodec { bits: 5 },
    IntNCodec { bits: 6 },
    IntNCodec { bits: 7 },
    IntNCodec { bits: 8 },
];

/// A tensor held in its packed storage format — the canonical host
/// representation of a grid weight (and of any checkpoint payload entry).
///
/// Invariant: `bytes.len() == format.packed_bytes(numel())`, established
/// by [`PackedTensor::pack`] / [`PackedTensor::from_bytes`] and relied on
/// by [`PackedTensor::unpack`].
#[derive(Clone, Debug, PartialEq)]
pub struct PackedTensor {
    pub format: Format,
    pub shape: Vec<usize>,
    /// AbsMean scale for grid formats; `None` for dense formats.
    pub scale: Option<f32>,
    pub bytes: Vec<u8>,
}

impl PackedTensor {
    /// Pack f32 values into `format`. `vals.len()` must match the shape's
    /// element count and grid formats require a scale.
    pub fn pack(
        vals: &[f32],
        shape: Vec<usize>,
        format: Format,
        scale: Option<f32>,
    ) -> Result<PackedTensor, String> {
        let numel = shape.iter().product::<usize>().max(1);
        if vals.len() != numel {
            return Err(format!(
                "shape {shape:?} wants {numel} values, got {}",
                vals.len()
            ));
        }
        let bytes = format.encode(vals, scale)?;
        Ok(PackedTensor {
            format,
            shape,
            scale,
            bytes,
        })
    }

    /// Adopt already-packed bytes (e.g. a checkpoint payload slice),
    /// validating the size invariant.
    pub fn from_bytes(
        bytes: Vec<u8>,
        shape: Vec<usize>,
        format: Format,
        scale: Option<f32>,
    ) -> Result<PackedTensor, String> {
        let numel = shape.iter().product::<usize>().max(1);
        check_len(&format.tag(), bytes.len(), format.packed_bytes(numel))?;
        if format.is_grid_format() && scale.is_none() {
            return Err(format!("{} codec needs scale", format.tag()));
        }
        Ok(PackedTensor {
            format,
            shape,
            scale,
            bytes,
        })
    }

    pub fn numel(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }

    /// Resident size in bytes — what this tensor actually costs the host.
    pub fn packed_bytes(&self) -> usize {
        self.bytes.len()
    }

    /// Decode back to f32 values.
    pub fn unpack(&self) -> Result<Vec<f32>, String> {
        self.format.decode(&self.bytes, self.numel(), self.scale)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_bits_is_the_only_sentinel() {
        assert_eq!(Format::from_bits(1.58), Format::Ternary2bit);
        assert_eq!(Format::from_bits(1.58 + 1e-12), Format::Ternary2bit);
        assert_eq!(Format::from_bits(8.0), Format::IntN(8));
        assert_eq!(Format::from_bits(3.0), Format::IntN(3));
        assert_eq!(Format::from_bits(2.0), Format::IntN(2));
    }

    #[test]
    fn for_entry_routing() {
        assert_eq!(
            Format::for_entry(true, 1.58, Format::F32),
            Format::Ternary2bit
        );
        assert_eq!(Format::for_entry(true, 4.0, Format::F32), Format::IntN(4));
        assert_eq!(Format::for_entry(false, 1.58, Format::Bf16), Format::Bf16);
    }

    #[test]
    fn tags_roundtrip() {
        let all = [
            Format::F32,
            Format::Bf16,
            Format::Fp8E4m3,
            Format::Ternary2bit,
            Format::IntN(2),
            Format::IntN(5),
            Format::IntN(8),
        ];
        for f in all {
            assert_eq!(Format::from_tag(&f.tag()).unwrap(), f);
        }
        assert!(Format::from_tag("int9").is_err());
        assert!(Format::from_tag("int1").is_err());
        assert!(Format::from_tag("nope").is_err());
    }

    #[test]
    fn packed_sizes_match_seed_codec() {
        assert_eq!(Format::F32.packed_bytes(100), 400);
        assert_eq!(Format::Bf16.packed_bytes(100), 200);
        assert_eq!(Format::Fp8E4m3.packed_bytes(100), 100);
        assert_eq!(Format::Ternary2bit.packed_bytes(100), 28);
        assert_eq!(Format::IntN(3).packed_bytes(100), 38);
        assert_eq!(Format::IntN(8).packed_bytes(100), 100);
    }

    #[test]
    fn bits_per_weight_from_registry() {
        assert_eq!(Format::F32.bits_per_weight(), 32.0);
        assert_eq!(Format::Bf16.bits_per_weight(), 16.0);
        assert_eq!(Format::Fp8E4m3.bits_per_weight(), 8.0);
        assert_eq!(Format::Ternary2bit.bits_per_weight(), 2.0);
        assert_eq!(Format::IntN(3).bits_per_weight(), 3.0);
    }

    #[test]
    fn grid_range_matches_paper() {
        assert_eq!(Format::Ternary2bit.grid_range(), (-1.0, 1.0));
        assert_eq!(Format::IntN(8).grid_range(), (-128.0, 127.0));
        assert_eq!(Format::IntN(2).grid_range(), (-2.0, 1.0));
    }

    #[test]
    fn out_of_range_widths_stay_arithmetic() {
        // seed semantics: range/size math works for any width; only the
        // packer lookup rejects unsupported widths
        assert_eq!(Format::from_bits(16.0), Format::IntN(16));
        assert_eq!(Format::IntN(16).grid_range(), (-32768.0, 32767.0));
        assert_eq!(Format::IntN(16).bits_per_weight(), 16.0);
    }

    #[test]
    #[should_panic(expected = "unsupported INT16")]
    fn unsupported_width_codec_lookup_panics() {
        let _ = Format::IntN(16).codec();
    }

    #[test]
    fn packed_tensor_roundtrip_all_formats() {
        let s = 25.0f32;
        let grid: Vec<f32> = (0..37).map(|i| ((i % 3) as f32 - 1.0) / s).collect();
        let dense: Vec<f32> = (0..37).map(|i| (i as f32 - 18.0) * 0.25).collect();
        for (fmt, vals, scale) in [
            (Format::F32, &dense, None),
            (Format::Ternary2bit, &grid, Some(s)),
            (Format::IntN(4), &grid, Some(s)),
        ] {
            let pt = PackedTensor::pack(vals, vec![37], fmt, scale).unwrap();
            assert_eq!(pt.packed_bytes(), fmt.packed_bytes(37));
            let back = pt.unpack().unwrap();
            for (a, b) in vals.iter().zip(back.iter()) {
                assert!((a - b).abs() < 1e-6, "{fmt:?}");
            }
        }
        // lossy dense formats: idempotent rather than exact
        for fmt in [Format::Bf16, Format::Fp8E4m3] {
            let pt = PackedTensor::pack(&dense, vec![37], fmt, None).unwrap();
            let once = pt.unpack().unwrap();
            let pt2 = PackedTensor::pack(&once, vec![37], fmt, None).unwrap();
            assert_eq!(pt.unpack().unwrap(), pt2.unpack().unwrap());
        }
    }

    #[test]
    fn packed_tensor_rejects_mismatches() {
        assert!(PackedTensor::pack(&[1.0; 5], vec![4], Format::F32, None).is_err());
        assert!(PackedTensor::pack(&[0.0; 4], vec![4], Format::Ternary2bit, None).is_err());
        assert!(PackedTensor::from_bytes(vec![0u8; 3], vec![4], Format::F32, None).is_err());
        assert!(
            PackedTensor::from_bytes(vec![0u8; 4], vec![4], Format::Ternary2bit, None).is_err()
        );
    }

    #[test]
    fn scalar_shape_numel_is_one() {
        let pt = PackedTensor::pack(&[1.5], vec![], Format::F32, None).unwrap();
        assert_eq!(pt.numel(), 1);
        assert_eq!(pt.unpack().unwrap(), vec![1.5]);
    }

    #[test]
    fn decode_validates_length() {
        assert!(Format::F32.decode(&[0u8; 7], 2, None).is_err());
        assert!(Format::Ternary2bit
            .decode(&[0u8; 3], 4, Some(1.0))
            .is_err());
        assert!(Format::IntN(4).decode(&[0u8; 1], 4, Some(1.0)).is_err());
    }
}
