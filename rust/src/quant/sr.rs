//! Host-side stochastic rounding (paper Eq. 1) + the counter-hash PRNG.
//!
//! The PRNG is the same 3-round xorshift-multiply mix as the Pallas kernel
//! (`python/compile/kernels/prng.py`), so given the same `(seed, counter)`
//! the host and the kernel draw identical uniforms — checkpoint conversions
//! done in Rust are bit-reproducible against the training graph.

const M1: u32 = 0x85EB_CA6B;
const M2: u32 = 0xC2B2_AE35;
const GOLDEN: u32 = 0x9E37_79B9;

/// Mix a (counter, seed) pair into uniform u32 bits — twin of `prng.hash_u32`.
#[inline]
pub fn hash_u32(counter: u32, seed: u32) -> u32 {
    let mut x = counter.wrapping_mul(GOLDEN).wrapping_add(seed);
    x = (x ^ (x >> 16)).wrapping_mul(M1);
    x = (x ^ (x >> 13)).wrapping_mul(M2);
    x ^ (x >> 16)
}

/// Uniform f32 in [0, 1) from (counter, seed); top 24 bits → exact mantissa.
#[inline]
pub fn uniform01(counter: u32, seed: u32) -> f32 {
    (hash_u32(counter, seed) >> 8) as f32 * (1.0 / (1 << 24) as f32)
}

/// Stochastically round one value onto the integer grid `[qn, qp]` scaled
/// by `s`: `SR(x*s)/s` with P(ceil) = frac(x*s).
#[inline]
pub fn sr_scalar(x: f32, counter: u32, seed: u32, qn: f32, qp: f32, s: f32) -> f32 {
    let y = x * s;
    let lo = y.floor();
    let frac = y - lo;
    let u = uniform01(counter, seed);
    let r = if u < frac { lo + 1.0 } else { lo };
    r.clamp(qn, qp) / s
}

/// SR an entire slice (counter = element index), matching the kernel's
/// row-major counter layout for a full (un-tiled) tensor.
pub fn sr_slice(xs: &[f32], seed: u32, bits: f64, s: f32) -> Vec<f32> {
    let (qn, qp) = super::qrange(bits);
    xs.iter()
        .enumerate()
        .map(|(i, &x)| sr_scalar(x, i as u32, seed, qn as f32, qp as f32, s))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hash_matches_python_twin() {
        // golden values from python/compile/kernels/prng.py — regenerate
        // with `python -m tests.test_interop` (pinned on both sides)
        assert_eq!(hash_u32(0, 0), 0);
        assert_eq!(hash_u32(1, 2), 3024231355);
        assert_eq!(hash_u32(12345, 67890), 2856791855);
        assert_eq!(hash_u32(4294967295, 1), 3893119930);
        // determinism + seed sensitivity
        assert_eq!(hash_u32(123, 456), hash_u32(123, 456));
        assert_ne!(hash_u32(123, 456), hash_u32(123, 457));
    }

    #[test]
    fn uniform_in_range_and_unbiased() {
        let n = 100_000u32;
        let mean: f64 = (0..n).map(|i| uniform01(i, 7) as f64).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.005, "{mean}");
        for i in 0..1000 {
            let u = uniform01(i, 3);
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn sr_support_and_unbiasedness() {
        let s = 1.0f32;
        let x = 0.37f32;
        let mut mean = 0.0f64;
        let n = 200_000;
        for i in 0..n {
            let r = sr_scalar(x, i, 11, -128.0, 127.0, s);
            assert!(r == 0.0 || r == 1.0);
            mean += r as f64;
        }
        mean /= n as f64;
        assert!((mean - 0.37).abs() < 0.005, "{mean}");
    }

    #[test]
    fn sr_exact_grid_points_fixed() {
        for k in -5..=5 {
            let x = k as f32 / 4.0;
            assert_eq!(sr_scalar(x, 9, 1, -128.0, 127.0, 4.0), x);
        }
    }

    #[test]
    fn sr_clips() {
        assert_eq!(sr_scalar(10.0, 0, 0, -1.0, 1.0, 1.0), 1.0);
        assert_eq!(sr_scalar(-10.0, 0, 0, -1.0, 1.0, 1.0), -1.0);
    }
}
