//! OCP FP8 codecs (E4M3 and E5M2) — encode/decode + value-level casts.
//!
//! E4M3: 1 sign, 4 exponent (bias 7), 3 mantissa. Max normal 448, min
//! normal 2⁻⁶, subnormal step 2⁻⁹. Following the OCP/MS-AMP convention the
//! cast *saturates* instead of producing inf. E5M2: 1/5/2, bias 15, max
//! 57344. Value-level behaviour is mirrored by `python/compile/lowp.py`.

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Format {
    E4M3,
    E5M2,
}

impl Format {
    fn mant_bits(self) -> i32 {
        match self {
            Format::E4M3 => 3,
            Format::E5M2 => 2,
        }
    }
    fn bias(self) -> i32 {
        match self {
            Format::E4M3 => 7,
            Format::E5M2 => 15,
        }
    }
    pub fn max_value(self) -> f32 {
        match self {
            Format::E4M3 => 448.0,
            Format::E5M2 => 57344.0,
        }
    }
    pub fn min_normal(self) -> f32 {
        match self {
            Format::E4M3 => 2f32.powi(-6),
            Format::E5M2 => 2f32.powi(-14),
        }
    }
    fn sub_step(self) -> f32 {
        self.min_normal() * 2f32.powi(-self.mant_bits())
    }
}

/// Encode an f32 into an 8-bit code (saturating, round-to-nearest-even).
pub fn encode(x: f32, fmt: Format) -> u8 {
    let sign = if x.is_sign_negative() { 0x80u8 } else { 0 };
    let ax = x.abs();
    if ax != ax {
        return sign | 0x7F; // NaN sentinel
    }
    if ax == 0.0 {
        return sign;
    }
    let m = fmt.mant_bits();
    let bias = fmt.bias();
    if ax < fmt.min_normal() {
        // subnormal: code = round(ax / sub_step)
        let k = round_half_even(ax / fmt.sub_step());
        if k == 0 {
            return sign;
        }
        if k < (1 << m) {
            return sign | k as u8;
        }
        // rounded up into the first normal binade
        return sign | (1 << m) as u8;
    }
    let ax = ax.min(fmt.max_value());
    let e = ax.log2().floor() as i32;
    let ulp = 2f32.powi(e - m);
    let mant = round_half_even(ax / ulp); // in [2^m, 2^(m+1)]
    let (e, mant) = if mant >= (2 << m) {
        (e + 1, 1 << m)
    } else {
        (e, mant)
    };
    let biased = e + bias;
    let max_biased = (1 << (match fmt {
        Format::E4M3 => 4,
        Format::E5M2 => 5,
    })) - 1;
    if biased >= max_biased + 1 {
        // overflow after rounding → saturate to max code
        return sign | max_code(fmt);
    }
    sign | ((biased as u8) << m) | ((mant - (1 << m)) as u8)
}

fn max_code(fmt: Format) -> u8 {
    match fmt {
        Format::E4M3 => 0x7E, // 448 = exp 15, mant 110 (E4M3 reserves 0x7F for NaN)
        Format::E5M2 => 0x7B, // 57344 = exp 30, mant 11
    }
}

fn round_half_even(x: f32) -> i32 {
    let f = x.floor();
    let d = x - f;
    let fi = f as i32;
    if d > 0.5 {
        fi + 1
    } else if d < 0.5 {
        fi
    } else if fi % 2 == 0 {
        fi
    } else {
        fi + 1
    }
}

/// Decode an 8-bit code back to f32 (exact).
pub fn decode(code: u8, fmt: Format) -> f32 {
    let sign = if code & 0x80 != 0 { -1.0f32 } else { 1.0 };
    let m = fmt.mant_bits();
    let bias = fmt.bias();
    let ebits = match fmt {
        Format::E4M3 => 4,
        Format::E5M2 => 5,
    };
    let e_field = ((code & 0x7F) >> m) as i32;
    let m_field = (code & ((1 << m) - 1)) as i32;
    if fmt == Format::E4M3 && (code & 0x7F) == 0x7F {
        return f32::NAN;
    }
    if fmt == Format::E5M2 && e_field == (1 << ebits) - 1 {
        return if m_field == 0 { sign * f32::INFINITY } else { f32::NAN };
    }
    if e_field == 0 {
        return sign * m_field as f32 * fmt.sub_step();
    }
    sign * (1.0 + m_field as f32 / (1 << m) as f32) * 2f32.powi(e_field - bias)
}

/// Value-level cast: what an f32 becomes when stored in `fmt`.
pub fn cast(x: f32, fmt: Format) -> f32 {
    decode(encode(x, fmt), fmt)
}

/// Cast a slice in place.
pub fn cast_slice(xs: &mut [f32], fmt: Format) {
    for x in xs.iter_mut() {
        *x = cast(*x, fmt);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn e4m3_exact_values() {
        for v in [
            0.0f32, 1.0, -1.0, 0.5, 448.0, -448.0, 2f32.powi(-6), 2f32.powi(-9),
            1.75, 240.0,
        ] {
            assert_eq!(cast(v, Format::E4M3), v, "{v}");
        }
    }

    #[test]
    fn e4m3_rounding_and_saturation() {
        assert_eq!(cast(1.0 + 2f32.powi(-4), Format::E4M3), 1.0);
        assert_eq!(cast(449.0, Format::E4M3), 448.0);
        assert_eq!(cast(1e9, Format::E4M3), 448.0);
        assert_eq!(cast(-1e9, Format::E4M3), -448.0);
        assert!((cast(0.0626, Format::E4M3) - 0.0625).abs() < 1e-7);
    }

    #[test]
    fn e4m3_subnormals() {
        let step = 2f32.powi(-9);
        assert_eq!(cast(step, Format::E4M3), step);
        assert_eq!(cast(0.4 * step, Format::E4M3), 0.0);
        let y = cast(2.5 * step, Format::E4M3);
        assert!(y == 2.0 * step || y == 3.0 * step); // half-even boundary
    }

    #[test]
    fn e5m2_range() {
        assert_eq!(cast(57344.0, Format::E5M2), 57344.0);
        assert_eq!(cast(60000.0, Format::E5M2), 57344.0);
        assert_eq!(cast(2f32.powi(-14), Format::E5M2), 2f32.powi(-14));
        assert_eq!(cast(2f32.powi(-16), Format::E5M2), 2f32.powi(-16));
        assert_eq!(cast(1000.0, Format::E5M2), 1024.0);
    }

    #[test]
    fn roundtrip_all_codes() {
        // every finite code must decode→encode to itself
        for fmt in [Format::E4M3, Format::E5M2] {
            for code in 0..=255u8 {
                let v = decode(code, fmt);
                if v.is_finite() {
                    let back = encode(v, fmt);
                    // -0.0 and +0.0 may alias; accept both zero codes
                    if v == 0.0 {
                        assert_eq!(back & 0x7F, 0);
                    } else {
                        assert_eq!(back, code, "fmt={fmt:?} code={code:#x} v={v}");
                    }
                }
            }
        }
    }

    #[test]
    fn idempotent_cast() {
        for i in -200..200 {
            let v = i as f32 * 1.37;
            for fmt in [Format::E4M3, Format::E5M2] {
                let y = cast(v, fmt);
                assert_eq!(cast(y, fmt), y);
            }
        }
    }
}
