//! Analytic GPU-memory model (paper Table 3, Fig. 3 x-axis, §A.3).
//!
//! Reproduces the paper's accounting of training-time memory per
//! (model, mode, precision env, optimizer):
//!
//!   weights   — BitNet keeps an FP32/BF16/FP8 *master* of every quantized
//!               matrix; DQT stores only the INTn grid (+ f32 scales), at
//!               the per-format cost published by the codec registry
//!               (`quant::codec::Format::bits_per_weight`).
//!   gradients — one value per trainable parameter in the env's precision.
//!   optimizer — AdamW: 2 states/param; Adafactor: row+col vectors for
//!               matrices (the §4.3 memory-efficient option).
//!   activations — batch × seq × hidden × layers × a fusion coefficient,
//!               in the env's compute precision (checkpointing-free, as the
//!               paper trains without gradient accumulation).
//!   framework — fixed per-GPU overhead (CUDA context, workspace), the
//!               reason Table 3's small models still show tens of GB.
//!
//! The model is calibrated against Table 3's GH200 readings and validated
//! in `report::table3` (relative savings must match; see EXPERIMENTS.md).

use crate::config::{Env, Mode, ModelConfig, Optimizer, VariantSpec};

/// Activation-memory fusion coefficient: how many live activation tensors
/// of size [B,S,H] per layer a non-checkpointed fwd+bwd keeps (empirical
/// for LLaMA-style blocks with flash-style attention fusion).
const ACT_COEFF: f64 = 14.0;
/// Attention score memory coefficient (B × heads × S × S), non-flash.
const SCORE_COEFF: f64 = 2.0;
/// Fixed per-GPU framework overhead (CUDA context, cuDNN workspace, NCCL
/// buffers …) in bytes — fitted to Table 3.
const FRAMEWORK_BYTES: f64 = 28.0e9;

#[derive(Clone, Debug)]
pub struct MemoryBreakdown {
    pub weights: f64,
    pub grads: f64,
    pub optimizer: f64,
    pub activations: f64,
    pub framework: f64,
}

impl MemoryBreakdown {
    pub fn total(&self) -> f64 {
        self.weights + self.grads + self.optimizer + self.activations + self.framework
    }
    pub fn total_mb(&self) -> f64 {
        self.total() / 1e6
    }
    /// Model-state-only total (excludes activations + framework): the
    /// portion the paper's §1 memory argument is about.
    pub fn state_bytes(&self) -> f64 {
        self.weights + self.grads + self.optimizer
    }

    pub fn to_json(&self) -> crate::util::json::Value {
        crate::util::json::Value::obj()
            .set("weights", self.weights)
            .set("grads", self.grads)
            .set("optimizer", self.optimizer)
            .set("activations", self.activations)
            .set("framework", self.framework)
            .set("total", self.total())
    }
}

/// Estimate the training-time memory of one variant on one device.
pub fn estimate(spec: &VariantSpec, include_framework: bool) -> Option<MemoryBreakdown> {
    let cfg = spec.model_config()?;
    Some(estimate_cfg(&cfg, spec, include_framework))
}

pub fn estimate_cfg(
    cfg: &ModelConfig,
    spec: &VariantSpec,
    include_framework: bool,
) -> MemoryBreakdown {
    let p_total = cfg.param_count() as f64;
    let p_quant = if spec.mode.quantized() {
        cfg.quantized_param_count() as f64
    } else {
        0.0
    };
    let p_dense = p_total - p_quant;
    let env_b = spec.env.bytes_per_value();

    // --- weights ---
    let weights = match spec.mode {
        // unquantized: all params in env precision
        Mode::Fp32 => p_total * env_b,
        // BitNet: master copy of quantized set in env precision + the
        // transient ternary forward copy (absmean re-quantization buffer)
        Mode::Bitnet158 => p_dense * env_b + p_quant * (env_b + 2.0 / 8.0),
        // DQT family: grid weights at their true bit width, no master —
        // the per-format cost comes from the codec registry
        Mode::Dqt | Mode::DqtAbsmax | Mode::DqtTernaryInf => {
            let bits = if matches!(spec.mode, Mode::DqtTernaryInf) {
                8.0
            } else {
                spec.bits
            };
            let bpw = crate::quant::Format::from_bits(bits).bits_per_weight();
            p_dense * env_b + p_quant * bpw / 8.0
        }
    };

    // --- gradients (one per trainable param, env precision) ---
    let grads = p_total * env_b;

    // --- optimizer state ---
    let optimizer = match spec.optimizer {
        Optimizer::Adamw => 2.0 * p_total * env_b,
        Optimizer::Adafactor => {
            // factored: per [n,m] matrix n+m values; ≈ 2·P/sqrt(dim) —
            // approximate with the dominant projection shapes
            let h = cfg.hidden_size as f64;
            let factored = 2.0 * p_total / h.sqrt();
            factored * env_b
        }
    };

    // --- activations ---
    let (b, s, h) = (
        cfg.batch_size as f64,
        cfg.max_seq_len as f64,
        cfg.hidden_size as f64,
    );
    let l = cfg.num_hidden_layers as f64;
    let heads = cfg.num_attention_heads as f64;
    let act_env_b = match spec.env {
        Env::Fp32 => 4.0,
        Env::Bf16 => 2.0,
        Env::Fp8 => 1.0,
    };
    let activations =
        (ACT_COEFF * b * s * h * l + SCORE_COEFF * b * heads * s * s * l) * act_env_b
            + b * s * cfg.vocab_size as f64 * 4.0; // logits stay f32

    MemoryBreakdown {
        weights,
        grads,
        optimizer,
        activations,
        framework: if include_framework { FRAMEWORK_BYTES } else { 0.0 },
    }
}

/// KV-cache bytes for serving: `2 · n_layer · seq_len · d_model · 4`
/// (keys + values, f32) per sequence, scaled by the number of
/// concurrently resident sequences (the scheduler's batch width).
pub fn kv_cache_bytes(cfg: &ModelConfig, batch: usize) -> f64 {
    2.0 * cfg.num_hidden_layers as f64
        * cfg.max_seq_len as f64
        * cfg.hidden_size as f64
        * 4.0
        * batch as f64
}

/// Serving-time memory of one variant: packed grid weights + dense
/// high-precision params + KV cache. This is the whole footprint of the
/// decode path — no gradients, no optimizer state, no f32 copies of the
/// quantized projections (the fused GEMV reads the 2-bit codes directly).
#[derive(Clone, Debug)]
pub struct ServingBreakdown {
    /// the quantized projections in their serving format (2-bit packed
    /// when ternary-effective, dense f32 for non-ternary integer grids)
    pub grid_weights: f64,
    /// embedding + norms (+ all params in unquantized modes), f32
    pub dense_weights: f64,
    pub kv_cache: f64,
    pub batch: usize,
}

impl ServingBreakdown {
    pub fn total(&self) -> f64 {
        self.grid_weights + self.dense_weights + self.kv_cache
    }

    pub fn to_json(&self) -> crate::util::json::Value {
        crate::util::json::Value::obj()
            .set("grid_weights", self.grid_weights)
            .set("dense_weights", self.dense_weights)
            .set("kv_cache", self.kv_cache)
            .set("batch", self.batch)
            .set("total", self.total())
    }
}

/// Estimate the serving footprint of `spec` at `batch` concurrent
/// sequences. `ternary` models §A.2 deploy-time projection (any quantized
/// variant serves 2-bit ternary); without it the stored grid format
/// decides — ternary grids serve packed, wider integer grids serve dense
/// f32 (no fused INTn kernel yet).
pub fn serving_estimate(spec: &VariantSpec, batch: usize, ternary: bool) -> Option<ServingBreakdown> {
    let cfg = spec.model_config()?;
    let p_total = cfg.param_count() as f64;
    let p_quant = if spec.mode.quantized() {
        cfg.quantized_param_count() as f64
    } else {
        0.0
    };
    let serves_ternary = match spec.mode {
        Mode::Fp32 => false,
        Mode::Bitnet158 | Mode::DqtTernaryInf => true,
        Mode::Dqt | Mode::DqtAbsmax => {
            ternary
                || crate::quant::Format::from_bits(spec.bits)
                    == crate::quant::Format::Ternary2bit
        }
    };
    let grid_weights = if serves_ternary {
        p_quant * crate::quant::Format::Ternary2bit.bits_per_weight() / 8.0
    } else {
        p_quant * 4.0
    };
    Some(ServingBreakdown {
        grid_weights,
        dense_weights: (p_total - p_quant) * 4.0,
        kv_cache: kv_cache_bytes(&cfg, batch),
        batch,
    })
}

/// Distributed data-parallel estimate for one variant at `workers` ranks:
/// what each rank keeps resident and what the training plane ships.
///
/// Data parallelism replicates the model state (weights + grads +
/// optimizer) on every rank and shards the *batch*, so activations divide
/// by the world while state does not — and the wire costs are where DQT's
/// §1 argument compounds: the per-step gradient exchange defaults to f32
/// (one full parameter-sized partial each way per worker link), shrinks
/// ~4×/~16× under `--grad-format int8|ternary` (stochastic rounding +
/// error feedback, `dist::wire`'s `PackedGradSet` framing — at the cost
/// of one f32 residual copy per rank), and the periodic weight resync
/// ships the 2-bit packed grid + scales, ~16× less than an f32 weight
/// broadcast (`GridSync` framing).
#[derive(Clone, Debug)]
pub struct DistBreakdown {
    pub workers: usize,
    /// weights + grads + optimizer resident on *each* rank (replicated)
    pub per_rank_state: f64,
    /// activation memory for one rank's contiguous batch shard
    pub per_rank_activations: f64,
    /// f32 gradient partial one worker link carries per step, each way
    /// (`--grad-format f32`, the default)
    pub grad_bytes_per_step: f64,
    /// the same partial stochastically rounded to int8 + absmax scales
    /// (`--grad-format int8`)
    pub grad_bytes_per_step_int8: f64,
    /// the same partial as 2-bit packed ternary (`--grad-format ternary`)
    pub grad_bytes_per_step_ternary: f64,
    /// error-feedback residual state a quantized exchange keeps resident
    /// per rank — one f32 copy of the gradient set, reported honestly
    /// (0 under f32)
    pub ef_residual_bytes: f64,
    /// one weight resync as f32 values (grid matrices + scales)
    pub sync_bytes_f32: f64,
    /// one weight resync as packed grid codes + f32 scales
    pub sync_bytes_packed: f64,
}

impl DistBreakdown {
    /// Traffic saved by syncing packed grids instead of f32.
    pub fn sync_ratio(&self) -> f64 {
        if self.sync_bytes_packed > 0.0 {
            self.sync_bytes_f32 / self.sync_bytes_packed
        } else {
            1.0
        }
    }

    /// Wire saved per step by an int8 / ternary gradient exchange.
    pub fn grad_ratio_int8(&self) -> f64 {
        if self.grad_bytes_per_step_int8 > 0.0 {
            self.grad_bytes_per_step / self.grad_bytes_per_step_int8
        } else {
            1.0
        }
    }

    pub fn grad_ratio_ternary(&self) -> f64 {
        if self.grad_bytes_per_step_ternary > 0.0 {
            self.grad_bytes_per_step / self.grad_bytes_per_step_ternary
        } else {
            1.0
        }
    }

    pub fn to_json(&self) -> crate::util::json::Value {
        crate::util::json::Value::obj()
            .set("workers", self.workers)
            .set("per_rank_state", self.per_rank_state)
            .set("per_rank_activations", self.per_rank_activations)
            .set("grad_bytes_per_step", self.grad_bytes_per_step)
            .set("grad_bytes_per_step_int8", self.grad_bytes_per_step_int8)
            .set(
                "grad_bytes_per_step_ternary",
                self.grad_bytes_per_step_ternary,
            )
            .set("ef_residual_bytes", self.ef_residual_bytes)
            .set("grad_ratio_int8", self.grad_ratio_int8())
            .set("grad_ratio_ternary", self.grad_ratio_ternary())
            .set("sync_bytes_f32", self.sync_bytes_f32)
            .set("sync_bytes_packed", self.sync_bytes_packed)
            .set("sync_ratio", self.sync_ratio())
    }
}

/// Estimate the distributed footprint of `spec` at `workers` ranks (the
/// `memory --workers N` CLI view and `report --exp dist`).
pub fn dist_estimate(spec: &VariantSpec, workers: usize) -> Option<DistBreakdown> {
    let cfg = spec.model_config()?;
    let workers = workers.max(1);
    let b = estimate_cfg(&cfg, spec, false);
    let p_total = cfg.param_count() as f64;
    let p_quant = if spec.mode.quantized() {
        cfg.quantized_param_count() as f64
    } else {
        0.0
    };
    // one f32 scale per grid matrix rides every resync
    let n_scales = if spec.mode.quantized() {
        (7 * cfg.num_hidden_layers) as f64
    } else {
        0.0
    };
    // the stored grid width (ternary-inf trains an 8-bit grid, like the
    // weights term above). Only DQT modes *have* an on-grid master to
    // pack: BitNet's masters are continuous, so its "packed" sync is the
    // same f32 broadcast.
    let bpw = match spec.mode {
        Mode::Dqt | Mode::DqtAbsmax => {
            crate::quant::Format::from_bits(spec.bits).bits_per_weight()
        }
        Mode::DqtTernaryInf => crate::quant::Format::from_bits(8.0).bits_per_weight(),
        Mode::Fp32 | Mode::Bitnet158 => 32.0,
    };
    Some(DistBreakdown {
        workers,
        per_rank_state: b.state_bytes(),
        per_rank_activations: b.activations / workers as f64,
        grad_bytes_per_step: p_total * 4.0,
        // SR + error feedback quantize *all* gradient buffers (the wire
        // codec is mode-agnostic): 1 byte/value for int8, 2 bits/value
        // for ternary, plus one f32 absmax scale per buffer (negligible,
        // not modeled here — the measured assertions in benches/dist.rs
        // cover the true frame overhead)
        grad_bytes_per_step_int8: p_total,
        grad_bytes_per_step_ternary: p_total
            * crate::quant::Format::Ternary2bit.bits_per_weight()
            / 8.0,
        // the honest cost of error feedback: one f32 residual per
        // gradient value, resident on every rank that quantizes its wire
        ef_residual_bytes: p_total * 4.0,
        sync_bytes_f32: p_quant * 4.0 + n_scales * 4.0,
        sync_bytes_packed: p_quant * bpw / 8.0 + n_scales * 4.0,
    })
}

/// Current process RSS in bytes (our own measured footprint, reported next
/// to the analytic model in the experiments).
pub fn process_rss_bytes() -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmRSS:") {
            let kb: u64 = rest.trim().trim_end_matches(" kB").trim().parse().ok()?;
            return Some(kb * 1024);
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Env, Mode, Optimizer, VariantSpec};

    fn spec(mode: Mode, bits: f64, env: Env, opt: Optimizer) -> VariantSpec {
        VariantSpec::new("p1b", mode, bits)
            .with_env(env)
            .with_optimizer(opt)
    }

    #[test]
    fn dqt_state_smaller_than_bitnet() {
        let d = estimate(&spec(Mode::Dqt, 8.0, Env::Fp32, Optimizer::Adamw), false).unwrap();
        let b = estimate(&spec(Mode::Bitnet158, 1.58, Env::Fp32, Optimizer::Adamw), false)
            .unwrap();
        assert!(d.weights < b.weights, "{} !< {}", d.weights, b.weights);
    }

    #[test]
    fn ternary_weights_are_16x_smaller_than_fp32() {
        let d = estimate(&spec(Mode::Dqt, 1.58, Env::Fp32, Optimizer::Adamw), false).unwrap();
        let f = estimate(&spec(Mode::Fp32, 1.58, Env::Fp32, Optimizer::Adamw), false).unwrap();
        // quantized set dominates p1b; ratio approaches 16 on that subset
        let cfg = ModelConfig::by_name("p1b").unwrap();
        let qfrac = cfg.quantized_param_count() as f64 / cfg.param_count() as f64;
        assert!(qfrac > 0.9);
        assert!(d.weights < f.weights * (1.0 - qfrac) + f.weights * qfrac / 14.0);
    }

    #[test]
    fn paper_intro_arithmetic() {
        // "a 1B LLM … 4GB in FP32 … ternary reduces this to 0.2GB"
        let cfg = ModelConfig::by_name("p1b").unwrap();
        let fp32_gb = cfg.param_count() as f64 * 4.0 / 1e9;
        let tern_gb = cfg.param_count() as f64 * 2.0 / 8.0 / 1e9;
        assert!((3.0..5.0).contains(&fp32_gb));
        assert!((0.15..0.3).contains(&tern_gb));
    }

    #[test]
    fn env_and_optimizer_monotonicity() {
        // fp32 > bf16 > fp8 total; adamw > adafactor
        let t = |env, opt| {
            estimate(&spec(Mode::Dqt, 8.0, env, opt), true)
                .unwrap()
                .total()
        };
        assert!(t(Env::Fp32, Optimizer::Adamw) > t(Env::Bf16, Optimizer::Adamw));
        assert!(t(Env::Bf16, Optimizer::Adamw) > t(Env::Fp8, Optimizer::Adamw));
        assert!(t(Env::Bf16, Optimizer::Adamw) > t(Env::Bf16, Optimizer::Adafactor));
        assert!(t(Env::Fp8, Optimizer::Adamw) > t(Env::Fp8, Optimizer::Adafactor));
    }

    #[test]
    fn table3_shape_check() {
        // Table 3 (1B): FP32 76.5GB, BF16 58.3, BF16+AF 53.7, FP8 40.9,
        // FP8+AF 37.7 — our model must reproduce the *ordering* and the
        // rough ratios (BitNet-style training, AdamW default).
        let t = |env, opt| {
            estimate(&spec(Mode::Bitnet158, 1.58, env, opt), true)
                .unwrap()
                .total()
        };
        let fp32 = t(Env::Fp32, Optimizer::Adamw);
        let bf16 = t(Env::Bf16, Optimizer::Adamw);
        let bf16_af = t(Env::Bf16, Optimizer::Adafactor);
        let fp8 = t(Env::Fp8, Optimizer::Adamw);
        let fp8_af = t(Env::Fp8, Optimizer::Adafactor);
        assert!(fp32 > bf16 && bf16 > bf16_af && bf16 > fp8 && fp8 > fp8_af);
        // paper ratio fp32/fp8 ≈ 1.87; accept a generous band
        let ratio = fp32 / fp8;
        assert!((1.3..2.8).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn rss_readable() {
        let rss = process_rss_bytes().unwrap();
        assert!(rss > 1_000_000);
    }

    #[test]
    fn kv_cache_formula_and_batch_scaling() {
        let cfg = ModelConfig::by_name("test").unwrap();
        // 2 · layers · seq · hidden · 4
        assert_eq!(kv_cache_bytes(&cfg, 1), 2.0 * 2.0 * 16.0 * 32.0 * 4.0);
        assert_eq!(kv_cache_bytes(&cfg, 16), 16.0 * kv_cache_bytes(&cfg, 1));
    }

    #[test]
    fn serving_ternary_is_grid_bytes_plus_kv() {
        let s = serving_estimate(&spec(Mode::Dqt, 1.58, Env::Fp32, Optimizer::Adamw), 1, false)
            .unwrap();
        let cfg = ModelConfig::by_name("p1b").unwrap();
        // quantized set at 2 bits/weight — the §1 deployment arithmetic
        assert_eq!(s.grid_weights, cfg.quantized_param_count() as f64 * 2.0 / 8.0);
        assert_eq!(
            s.dense_weights,
            (cfg.param_count() - cfg.quantized_param_count()) as f64 * 4.0
        );
        assert_eq!(s.kv_cache, kv_cache_bytes(&cfg, 1));
        // serving is a small fraction of the training-state footprint
        let train = estimate(&spec(Mode::Dqt, 1.58, Env::Fp32, Optimizer::Adamw), false)
            .unwrap();
        assert!(s.total() < train.state_bytes() / 4.0);
    }

    #[test]
    fn dist_estimate_packed_sync_is_16x_cheaper_for_ternary() {
        let d = dist_estimate(&spec(Mode::Dqt, 1.58, Env::Fp32, Optimizer::Adamw), 4).unwrap();
        // 2 bits vs 32 bits, scales amortized away at p1b size
        assert!(d.sync_ratio() > 14.0, "ratio {}", d.sync_ratio());
        assert!(d.sync_bytes_packed < d.sync_bytes_f32 / 10.0);
        // the per-step gradient exchange is a full f32 parameter set
        let cfg = ModelConfig::by_name("p1b").unwrap();
        assert_eq!(d.grad_bytes_per_step, cfg.param_count() as f64 * 4.0);
        // quantized exchange tiers: int8 is 4x, ternary 2-bit is 16x, and
        // the error-feedback residual is one f32 copy of the gradients
        assert_eq!(d.grad_bytes_per_step_int8, cfg.param_count() as f64);
        assert!((d.grad_ratio_int8() - 4.0).abs() < 1e-9, "{}", d.grad_ratio_int8());
        assert!((d.grad_ratio_ternary() - 16.0).abs() < 1e-9, "{}", d.grad_ratio_ternary());
        assert_eq!(d.ef_residual_bytes, d.grad_bytes_per_step);
        let j = d.to_json();
        assert!(j.get("grad_bytes_per_step_int8").is_some());
        assert!(j.get("ef_residual_bytes").is_some());
        // state replicates; activations shard with the batch
        let d1 = dist_estimate(&spec(Mode::Dqt, 1.58, Env::Fp32, Optimizer::Adamw), 1).unwrap();
        assert_eq!(d.per_rank_state, d1.per_rank_state);
        assert_eq!(d.per_rank_activations * 4.0, d1.per_rank_activations);
        // int8 grids still pack 4×
        let d8 = dist_estimate(&spec(Mode::Dqt, 8.0, Env::Fp32, Optimizer::Adamw), 4).unwrap();
        assert!((d8.sync_ratio() - 4.0).abs() < 0.2, "{}", d8.sync_ratio());
    }

    #[test]
    fn dist_estimate_non_grid_modes_cannot_pack() {
        // BitNet masters are continuous; fp32 has nothing quantized at all
        let b = dist_estimate(&spec(Mode::Bitnet158, 1.58, Env::Fp32, Optimizer::Adamw), 2)
            .unwrap();
        assert_eq!(b.sync_bytes_packed, b.sync_bytes_f32);
        assert!(b.sync_bytes_f32 > 0.0);
        let f = dist_estimate(&spec(Mode::Fp32, 1.58, Env::Fp32, Optimizer::Adamw), 2).unwrap();
        assert_eq!(f.sync_bytes_f32, 0.0);
        assert_eq!(f.sync_ratio(), 1.0);
        // json carries the ratio
        let j = b.to_json();
        assert!(j.get("sync_ratio").is_some());
        assert_eq!(j.get("workers").unwrap().as_usize(), Some(2));
    }

    #[test]
    fn serving_modes_and_ternary_override() {
        let tern = |mode, bits, t| {
            serving_estimate(&spec(mode, bits, Env::Fp32, Optimizer::Adamw), 1, t)
                .unwrap()
                .grid_weights
        };
        // int8 grids serve dense f32 unless §A.2 projection is forced
        assert!(tern(Mode::Dqt, 8.0, false) > tern(Mode::Dqt, 8.0, true));
        assert_eq!(tern(Mode::Dqt, 8.0, true), tern(Mode::Dqt, 1.58, false));
        // BitNet and dqt_ternary_inf always serve ternary
        assert_eq!(tern(Mode::Bitnet158, 1.58, false), tern(Mode::Dqt, 1.58, false));
        assert_eq!(tern(Mode::DqtTernaryInf, 8.0, false), tern(Mode::Dqt, 1.58, false));
        // fp32 has no grid at all
        assert_eq!(tern(Mode::Fp32, 1.58, false), 0.0);
        // json renders with a total
        let s = serving_estimate(&spec(Mode::Dqt, 1.58, Env::Fp32, Optimizer::Adamw), 4, false)
            .unwrap();
        let j = s.to_json();
        assert!(j.get("total").is_some() && j.get("kv_cache").is_some());
        assert_eq!(j.get("batch").unwrap().as_usize(), Some(4));
    }
}
