//! Distributed determinism — the dist subsystem's contract, pinned end to
//! end over real localhost TCP: a 2-worker data-parallel ternary training
//! run must be **bitwise identical** to the 1-worker run — loss curve,
//! final state, eval NLL — and every rank must hold the same replica at
//! the end. The ranks deliberately run *different kernel thread counts*
//! (1 vs 2), composing this contract with PR 4's thread-invariance: the
//! reduction tree is fixed by global batch row indices, so neither the
//! transport nor the pool can move a bit. The required CI `dist-smoke`
//! job re-checks the same property across OS processes via the CLI.
//!
//! `--grad-format int8` trades that bitwise contract for a *convergence*
//! contract — the quantized-exchange loss curve must track the f32 curve
//! within a pinned tolerance while moving ~4x fewer wire bytes — pinned
//! here by `int8_gradient_exchange_tracks_the_f32_curve_and_shrinks_the_wire`.

use std::net::TcpListener;
use std::sync::Arc;
use std::time::Duration;

use dqt::config::{DistConfig, GradFormat, Mode, TrainConfig, VariantSpec};
use dqt::data::Pipeline;
use dqt::dist::{rendezvous_variant, Collective, DistExchange};
use dqt::kernels::Pool;
use dqt::runtime::{GradReducer, Manifest, NoReduce, State, VariantRuntime};
use dqt::train::{RunMetrics, StepExchange, Trainer};

const STEPS: u64 = 12;

fn tcfg() -> TrainConfig {
    TrainConfig {
        steps: STEPS,
        warmup_steps: 2,
        peak_lr: 2e-3,
        dataset: "tiny".into(),
        seed: 42,
        log_every: 0,
        eval_every: 0,
        ..TrainConfig::default()
    }
}

fn dcfg(world: usize, rank: usize, sync_every: u64, packed: bool) -> DistConfig {
    DistConfig {
        world,
        rank,
        addr: "127.0.0.1:0".into(),
        sync_every,
        packed_sync: packed,
        ..DistConfig::default()
    }
}

/// Train one rank to completion on its own backend + pipeline.
fn run_rank(col: Collective, d: &DistConfig, threads: usize) -> (State, RunMetrics, u64) {
    let vrt = VariantRuntime::native_with_pool(
        &VariantSpec::new("test", Mode::Dqt, 1.58),
        Arc::new(Pool::new(threads)),
    )
    .unwrap();
    let m = vrt.manifest();
    let pipeline = Pipeline::build(
        "tiny",
        42,
        m.variant.model.vocab_size,
        m.variant.model.max_seq_len,
    )
    .unwrap();
    let mut ex = DistExchange::new(col, d);
    let (state, metrics) = Trainer::new(&vrt, &pipeline, tcfg())
        .run_sharded(&mut ex)
        .unwrap();
    let sync_bytes = ex.sync_bytes();
    ex.into_collective().shutdown().unwrap();
    (state, metrics, sync_bytes)
}

fn assert_metrics_bitwise(a: &RunMetrics, b: &RunMetrics, what: &str) {
    assert_eq!(a.records.len(), b.records.len(), "{what}: step counts");
    for (x, y) in a.records.iter().zip(b.records.iter()) {
        assert_eq!(x.loss.to_bits(), y.loss.to_bits(), "{what}: loss @ {}", x.step);
        assert_eq!(
            x.upd_frac.to_bits(),
            y.upd_frac.to_bits(),
            "{what}: upd_frac @ {}",
            x.step
        );
        assert_eq!(x.gnorm.to_bits(), y.gnorm.to_bits(), "{what}: gnorm @ {}", x.step);
        assert_eq!(x.lr.to_bits(), y.lr.to_bits(), "{what}: lr @ {}", x.step);
    }
    assert_eq!(
        a.final_dev_loss.unwrap().to_bits(),
        b.final_dev_loss.unwrap().to_bits(),
        "{what}: eval NLL"
    );
}

fn assert_states_bitwise(a: &State, b: &State, what: &str) {
    assert_eq!(a.params.len(), b.params.len());
    for (i, (x, y)) in a.params.iter().zip(b.params.iter()).enumerate() {
        assert_eq!(x, y, "{what}: param {i}");
    }
    for (x, y) in a.opt.iter().zip(b.opt.iter()) {
        assert_eq!(x, y, "{what}: optimizer state");
    }
}

/// Launch a 2-rank world over localhost TCP (rank 1 on its own thread,
/// with its own backend, pipeline and a *different* pool width) and
/// return both ranks' results.
fn run_world_2(
    sync_every: u64,
    packed: bool,
) -> ((State, RunMetrics, u64), (State, RunMetrics, u64)) {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let worker = std::thread::spawn(move || {
        let variant = VariantSpec::new("test", Mode::Dqt, 1.58).variant_name();
        let col =
            Collective::join(&addr, 1, 2, &variant, Duration::from_secs(30)).unwrap();
        run_rank(col, &dcfg(2, 1, sync_every, packed), 2)
    });
    let variant = VariantSpec::new("test", Mode::Dqt, 1.58).variant_name();
    let col = Collective::host(listener, 2, &variant, Duration::from_secs(30)).unwrap();
    let rank0 = run_rank(col, &dcfg(2, 0, sync_every, packed), 1);
    let rank1 = worker.join().unwrap();
    (rank0, rank1)
}

/// Like [`run_rank`] but under a chosen gradient wire format, returning
/// the rank's cumulative all-reduce wire bytes instead of sync bytes.
fn run_rank_gf(col: Collective, d: &DistConfig, threads: usize) -> (State, RunMetrics, u64) {
    let vrt = VariantRuntime::native_with_pool(
        &VariantSpec::new("test", Mode::Dqt, 1.58),
        Arc::new(Pool::new(threads)),
    )
    .unwrap();
    let m = vrt.manifest();
    let pipeline = Pipeline::build(
        "tiny",
        42,
        m.variant.model.vocab_size,
        m.variant.model.max_seq_len,
    )
    .unwrap();
    let mut ex = DistExchange::new(col, d);
    let (state, metrics) = Trainer::new(&vrt, &pipeline, tcfg())
        .run_sharded(&mut ex)
        .unwrap();
    let wire = ex.allreduce_bytes();
    ex.into_collective().shutdown().unwrap();
    (state, metrics, wire)
}

/// 2-rank world (no grid resync) exchanging gradients as `gf`; both
/// ranks' results plus their all-reduce wire bytes.
fn run_world_2_gf(gf: GradFormat) -> ((State, RunMetrics, u64), (State, RunMetrics, u64)) {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let variant = VariantSpec::new("test", Mode::Dqt, 1.58).variant_name();
    let rv = rendezvous_variant(&variant, gf);
    let worker = {
        let rv = rv.clone();
        std::thread::spawn(move || {
            let col = Collective::join(&addr, 1, 2, &rv, Duration::from_secs(30)).unwrap();
            let d = DistConfig { grad_format: gf, ..dcfg(2, 1, 0, true) };
            run_rank_gf(col, &d, 2)
        })
    };
    let col = Collective::host(listener, 2, &rv, Duration::from_secs(30)).unwrap();
    let d = DistConfig { grad_format: gf, ..dcfg(2, 0, 0, true) };
    let rank0 = run_rank_gf(col, &d, 1);
    let rank1 = worker.join().unwrap();
    (rank0, rank1)
}

/// The acceptance pin: 2-worker run ≡ 1-worker run, bit for bit, with the
/// packed grid resync active — and both ranks end as identical replicas.
#[test]
fn two_worker_tcp_run_is_bitwise_equal_to_one_worker() {
    let (solo_state, solo_metrics, solo_sync) =
        run_rank(Collective::solo(), &dcfg(1, 0, 5, true), 1);
    assert_eq!(solo_sync, 0, "a solo world has nothing to sync");
    assert_eq!(solo_metrics.records.len(), STEPS as usize);

    let ((state0, metrics0, sync0), (state1, metrics1, sync1)) = run_world_2(5, true);
    assert_metrics_bitwise(&solo_metrics, &metrics0, "2-worker vs 1-worker (rank 0)");
    assert_states_bitwise(&solo_state, &state0, "2-worker vs 1-worker (rank 0)");
    // both ranks are bit-identical replicas, and the worker's own metrics
    // agree with rank 0's — the loss really is the global batch loss
    assert_metrics_bitwise(&metrics0, &metrics1, "rank 0 vs rank 1");
    assert_states_bitwise(&state0, &state1, "rank 0 vs rank 1");
    // the resync actually shipped packed bytes (steps 5 and 10)
    assert!(sync0 > 0 && sync1 == sync0, "sync bytes: {sync0} vs {sync1}");
}

/// The resync format and cadence cannot perturb the run: syncing f32
/// instead of packed grids, or not syncing at all, still lands on the
/// same bits — and the packed frames are measurably smaller than f32.
#[test]
fn sync_format_and_cadence_do_not_change_the_bits() {
    let (solo_state, solo_metrics, _) = run_rank(Collective::solo(), &dcfg(1, 0, 0, true), 1);
    let ((state_none, metrics_none, sync_none), _) = run_world_2(0, true);
    assert_eq!(sync_none, 0);
    assert_metrics_bitwise(&solo_metrics, &metrics_none, "no-sync run");
    assert_states_bitwise(&solo_state, &state_none, "no-sync run");

    let ((state_f32, metrics_f32, bytes_f32), _) = run_world_2(4, false);
    assert_metrics_bitwise(&solo_metrics, &metrics_f32, "f32-sync run");
    assert_states_bitwise(&solo_state, &state_f32, "f32-sync run");

    let ((_, _, bytes_packed), _) = run_world_2(4, true);
    assert!(
        bytes_packed * 4 < bytes_f32,
        "packed sync {bytes_packed} bytes should be far under f32 sync {bytes_f32}"
    );
}

/// Loss-curve tolerance (nats) for the int8 gradient-exchange contract:
/// over the 12-step smoke run the quantized curve must stay within this
/// of the f32 curve at every step and at the final dev eval. SR error on
/// an int8 grid with per-tensor absmax scaling plus error feedback keeps
/// the observed gap ~100x below this bound; the margin absorbs seed churn.
const INT8_LOSS_TOL: f32 = 0.35;

/// The quantized-exchange contract, the convergence analogue of the
/// bitwise pin above: `--grad-format int8` must (a) keep both ranks in
/// bit-identical lockstep (every rank adopts the same dequantized
/// broadcast), (b) track the f32 loss curve within [`INT8_LOSS_TOL`]
/// while genuinely perturbing the bits (non-vacuity), and (c) move ≥3.9x
/// fewer all-reduce wire bytes than the f32 exchange.
#[test]
fn int8_gradient_exchange_tracks_the_f32_curve_and_shrinks_the_wire() {
    let ((_, f32_metrics, f32_wire), _) = run_world_2_gf(GradFormat::F32);
    // the f32 leg through the gf plumbing is still the bitwise run
    let (_, solo_metrics, _) = run_rank(Collective::solo(), &dcfg(1, 0, 0, true), 1);
    assert_metrics_bitwise(&solo_metrics, &f32_metrics, "w2 f32 via grad-format path");

    let ((q_state0, q_metrics0, q_wire0), (q_state1, q_metrics1, q_wire1)) =
        run_world_2_gf(GradFormat::Int8);

    // (a) replica lockstep survives quantization: both ranks adopt the
    // same dequantized broadcast, so they stay bitwise-equal replicas
    assert_metrics_bitwise(&q_metrics0, &q_metrics1, "int8 rank 0 vs rank 1");
    assert_states_bitwise(&q_state0, &q_state1, "int8 rank 0 vs rank 1");

    // (b) convergence: every step within tolerance of the f32 curve...
    assert_eq!(q_metrics0.records.len(), f32_metrics.records.len());
    for (q, f) in q_metrics0.records.iter().zip(f32_metrics.records.iter()) {
        assert!(
            (q.loss - f.loss).abs() <= INT8_LOSS_TOL,
            "step {}: int8 loss {} drifted from f32 loss {}",
            q.step,
            q.loss,
            f.loss
        );
    }
    assert!(
        (q_metrics0.final_dev_loss.unwrap() - f32_metrics.final_dev_loss.unwrap()).abs()
            <= INT8_LOSS_TOL,
        "final dev loss: int8 {:?} vs f32 {:?}",
        q_metrics0.final_dev_loss,
        f32_metrics.final_dev_loss
    );
    // ...while actually changing bits somewhere — a vacuously-passing
    // quantizer (e.g. one that secretly ships f32) would fail this
    assert!(
        q_metrics0
            .records
            .iter()
            .zip(f32_metrics.records.iter())
            .any(|(q, f)| q.loss.to_bits() != f.loss.to_bits()),
        "int8 curve is bitwise equal to f32 — quantization isn't happening"
    );

    // (c) the wire shrinks: whole-frame ratio approaches 4.0 from below
    // as metadata amortizes; 3.9 leaves room for the test model's size
    assert_eq!(q_wire0, q_wire1, "both ranks move the same wire bytes");
    assert!(
        (f32_wire as f64) / (q_wire0 as f64) > 3.9,
        "int8 all-reduce wire {q_wire0} should be >3.9x under f32 {f32_wire}"
    );
}

/// `run_sharded` enforces the determinism contract's world constraint.
#[test]
fn run_sharded_rejects_illegal_worlds() {
    struct FakeExchange {
        world: usize,
        nr: NoReduce,
    }
    impl StepExchange for FakeExchange {
        fn rank(&self) -> usize {
            0
        }
        fn world(&self) -> usize {
            self.world
        }
        fn reducer(&mut self) -> &mut dyn GradReducer {
            &mut self.nr
        }
        fn sync_state(
            &mut self,
            _m: &Manifest,
            _s: &mut State,
            _step: u64,
        ) -> anyhow::Result<u64> {
            Ok(0)
        }
    }
    let vrt = VariantRuntime::native(&VariantSpec::new("test", Mode::Dqt, 1.58)).unwrap();
    let m = vrt.manifest();
    let pipeline = Pipeline::build(
        "tiny",
        42,
        m.variant.model.vocab_size,
        m.variant.model.max_seq_len,
    )
    .unwrap();
    // "test" has a 2-row global batch: world 3 is not a power of two,
    // world 4 does not divide it
    for (world, needle) in [(3usize, "power of two"), (4, "does not divide")] {
        let err = Trainer::new(&vrt, &pipeline, tcfg())
            .run_sharded(&mut FakeExchange { world, nr: NoReduce })
            .unwrap_err();
        assert!(err.to_string().contains(needle), "world {world}: {err}");
    }
}
