//! End-to-end training on the native CPU backend — the repo's proof that
//! the paper's loop (SR updates straight to the quantized grids, no FP32
//! masters) actually executes and converges, on any machine, with no
//! artifacts, PJRT or Python. The `e2e-smoke-train` CI job runs this file
//! as a required check on every PR.
//!
//! Also pins the determinism contract: `step_seed`/`hash_u32` golden
//! values, and bitwise-identical loss curves across two runs of the same
//! seed (the native-backend golden-curve guarantee).

use dqt::config::{BackendKind, Mode, TrainConfig, VariantSpec};
use dqt::data::Pipeline;
use dqt::eval;
use dqt::quant::sr::hash_u32;
use dqt::runtime::VariantRuntime;
use dqt::train::{checkpoint, step_seed, RunMetrics, Trainer};

fn native(spec: &VariantSpec) -> VariantRuntime {
    VariantRuntime::native(spec).expect("native backend")
}

fn pipeline_for(vrt: &VariantRuntime) -> Pipeline {
    let m = vrt.manifest();
    Pipeline::build(
        "tiny",
        1,
        m.variant.model.vocab_size,
        m.variant.model.max_seq_len,
    )
    .unwrap()
}

fn train(vrt: &VariantRuntime, steps: u64, seed: u64, peak_lr: f64) -> RunMetrics {
    let pipeline = pipeline_for(vrt);
    let cfg = TrainConfig {
        steps,
        warmup_steps: (steps / 10).max(2),
        peak_lr,
        dataset: "tiny".into(),
        seed,
        log_every: 0,
        eval_every: 0,
        ..TrainConfig::default()
    };
    let (_, metrics) = Trainer::new(vrt, &pipeline, cfg).run().unwrap();
    metrics
}

/// The acceptance check: a tiny ternary DQT variant trains ~50 steps end
/// to end on the native backend; loss decreases and SR updates actually
/// land on the grid (`upd_frac > 0`).
#[test]
fn e2e_smoke_train_ternary_loss_decreases() {
    let vrt = native(&VariantSpec::new("test", Mode::Dqt, 1.58));
    assert_eq!(vrt.backend_name(), "native");
    let metrics = train(&vrt, 50, 42, 2e-3);
    assert_eq!(metrics.records.len(), 50);
    let head: f32 = metrics.records[..5].iter().map(|r| r.loss).sum::<f32>() / 5.0;
    let tail = metrics.tail_loss(5).unwrap();
    assert!(
        tail < head,
        "loss did not decrease on the native backend: {head} -> {tail}"
    );
    assert!(metrics.records.iter().all(|r| r.loss.is_finite()));
    assert!(
        metrics.peak_upd_frac().unwrap() > 0.0,
        "no SR updates landed (upd_frac stayed 0)"
    );
    assert!(metrics.final_dev_loss.unwrap().is_finite());
}

/// Every core mode trains under the native backend (Fig. 2 family).
#[test]
fn all_core_modes_train_natively() {
    for (mode, bits) in [
        (Mode::Fp32, 1.58),
        (Mode::Bitnet158, 1.58),
        (Mode::Dqt, 8.0),
    ] {
        let vrt = native(&VariantSpec::new("test", mode, bits));
        let metrics = train(&vrt, 16, 42, 2e-3);
        assert!(
            metrics.records.iter().all(|r| r.loss.is_finite()),
            "{mode:?}"
        );
        let head: f32 = metrics.records[..4].iter().map(|r| r.loss).sum::<f32>() / 4.0;
        let tail = metrics.tail_loss(4).unwrap();
        assert!(tail < head, "{mode:?}: {head} -> {tail}");
    }
}

/// Golden loss curve: the same seed produces bitwise-identical metrics
/// across two runs, and a different seed does not.
#[test]
fn golden_curve_same_seed_is_bitwise_identical() {
    let vrt = native(&VariantSpec::new("test", Mode::Dqt, 1.58));
    let a = train(&vrt, 10, 7, 1e-3);
    let b = train(&vrt, 10, 7, 1e-3);
    assert_eq!(a.records.len(), b.records.len());
    for (x, y) in a.records.iter().zip(b.records.iter()) {
        assert_eq!(x.loss.to_bits(), y.loss.to_bits(), "step {}", x.step);
        assert_eq!(x.upd_frac.to_bits(), y.upd_frac.to_bits());
        assert_eq!(x.gnorm.to_bits(), y.gnorm.to_bits());
    }
    assert_eq!(a.final_dev_loss.unwrap(), b.final_dev_loss.unwrap());
    let c = train(&vrt, 10, 8, 1e-3);
    assert!(a
        .records
        .iter()
        .zip(c.records.iter())
        .any(|(x, y)| x.loss != y.loss));
}

/// The per-step SR seed derivation is a pinned contract — the same
/// `(run_seed, step)` must map to the same u32 forever, or historic runs
/// stop being reproducible.
#[test]
fn step_seed_and_hash_are_pinned() {
    assert_eq!(step_seed(42, 0), 142_593_372);
    assert_eq!(step_seed(42, 1), 939_911_724);
    assert_eq!(step_seed(42, 50), 41_768_088);
    assert_eq!(step_seed(7, 5), 1_915_552_099);
    assert_eq!(step_seed(0, 0), 0);
    // the run seed folds in its high 32 bits
    assert_eq!(step_seed((1u64 << 40) + 3, 2), 1_962_880_497);
    assert_ne!(step_seed(3, 2), step_seed((1u64 << 40) + 3, 2));
    // hash golden values (twin of the python kernel PRNG)
    assert_eq!(hash_u32(3, 9), 3_629_876_710);
    assert_eq!(hash_u32(12345, 67890), 2_856_791_855);
}

/// Native-trained states round-trip the format-true checkpoint codec and
/// resume bit-identically — the native backend and the `.dqt` wire format
/// compose.
#[test]
fn native_checkpoint_roundtrip_and_resume() {
    let vrt = native(&VariantSpec::new("test", Mode::Dqt, 1.58));
    let m = vrt.manifest();
    let pipeline = pipeline_for(&vrt);
    let loader = pipeline.loader(m.variant.model.batch_size, 6, 42);
    let mut state = vrt.init_state(42).unwrap();
    let mut last_batch = None;
    while let Some(b) = loader.next() {
        if b.step == 5 {
            last_batch = Some(b);
            break;
        }
        let (s2, _) = vrt
            .train_step(state, &b.tokens, step_seed(42, b.step), 1e-3)
            .unwrap();
        state = s2;
    }
    let dir = std::env::temp_dir().join("dqt_native_e2e_ckpt");
    let path = dir.join("model.dqt");
    checkpoint::save(&path, m, &state, checkpoint::Codec::F32, true).unwrap();
    let loaded = checkpoint::load_packed(&path, m).unwrap();
    // grid params come back packed at the wire bit width…
    assert!(m
        .params
        .iter()
        .zip(&loaded.params)
        .filter(|(meta, _)| meta.is_grid())
        .all(|(_, p)| p.is_packed()));
    // …and the resumed step equals the in-memory one exactly
    let batch = last_batch.unwrap();
    let seed = step_seed(42, 5);
    let (_, met_mem) = vrt.train_step(state, &batch.tokens, seed, 1e-3).unwrap();
    let (_, met_load) = vrt.train_step(loaded, &batch.tokens, seed, 1e-3).unwrap();
    assert_eq!(met_mem.loss.to_bits(), met_load.loss.to_bits());
    assert_eq!(met_mem.upd_frac, met_load.upd_frac);
    std::fs::remove_dir_all(dir).ok();
}

/// The eval harness (perplexity + ternary §A.2 projection) runs on the
/// native backend through the same `VariantRuntime` surface.
#[test]
fn native_eval_harness_and_ternary_inference() {
    let vrt = native(&VariantSpec::new("test", Mode::Dqt, 8.0));
    assert!(vrt.has_ternary_inference());
    let pipeline = pipeline_for(&vrt);
    let state = vrt.init_state(3).unwrap();
    let ppl8 = eval::perplexity(&vrt, &state, &pipeline, false).unwrap();
    let ppl3 = eval::perplexity(&vrt, &state, &pipeline, true).unwrap();
    assert!(ppl8.is_finite() && ppl8 > 1.0);
    assert!(ppl3.is_finite() && ppl3 > 1.0);
    assert_ne!(ppl8, ppl3); // ternary projection must change the model
}

/// `BackendKind::Auto` falls back to the native backend when no real
/// PJRT runtime is linked (the stub build), so zero-dependency training
/// is the default everywhere.
#[test]
fn auto_backend_resolves_without_pjrt() {
    let spec = VariantSpec::new("test", Mode::Dqt, 1.58);
    let res = VariantRuntime::open(
        BackendKind::Auto,
        None,
        dqt::default_artifacts_root(),
        &spec,
    );
    if dqt::runtime::pjrt_available() {
        // with a real PJRT runtime linked, Auto routes to artifacts —
        // which may legitimately be unbuilt in this checkout
        if let Ok(vrt) = res {
            assert_eq!(vrt.backend_name(), "pjrt");
        }
    } else {
        assert_eq!(res.unwrap().backend_name(), "native");
    }
}
