//! Determinism under parallelism — the kernel layer's contract, pinned
//! end to end: a real multi-step ternary training run and real KV-cached
//! decode steps must be **bitwise identical** at 1 kernel thread and at
//! several. The CI smoke matrix re-runs the e2e jobs under
//! `DQT_THREADS=1` and `DQT_THREADS=4`; this file pins the same property
//! in-process with explicit pools, so a violation fails fast with the
//! offending step/logit identified.

use std::sync::Arc;

use dqt::config::{Mode, Precision, TrainConfig, VariantSpec};
use dqt::data::Pipeline;
use dqt::kernels::Pool;
use dqt::runtime::VariantRuntime;
use dqt::serve::{Engine, GenParams};
use dqt::train::Trainer;

fn vrt_with(threads: usize) -> VariantRuntime {
    VariantRuntime::native_with_pool(
        &VariantSpec::new("test", Mode::Dqt, 1.58),
        Arc::new(Pool::new(threads)),
    )
    .expect("native backend")
}

fn vrt_fast(threads: usize) -> VariantRuntime {
    VariantRuntime::native_with_pool(
        &VariantSpec::new("test", Mode::Dqt, 1.58),
        Arc::new(Pool::with_precision(threads, Precision::Fast)),
    )
    .expect("native backend")
}

fn pipeline_for(vrt: &VariantRuntime) -> Pipeline {
    let m = vrt.manifest();
    Pipeline::build(
        "tiny",
        1,
        m.variant.model.vocab_size,
        m.variant.model.max_seq_len,
    )
    .unwrap()
}

/// A 20-step ternary train run produces a bitwise-identical loss curve —
/// and a bitwise-identical final state — at 1 and 4 kernel threads.
#[test]
fn ternary_train_run_is_bitwise_identical_across_thread_counts() {
    let run = |threads: usize| {
        let vrt = vrt_with(threads);
        assert_eq!(vrt.threads(), threads);
        let pipeline = pipeline_for(&vrt);
        let cfg = TrainConfig {
            steps: 20,
            warmup_steps: 2,
            peak_lr: 2e-3,
            dataset: "tiny".into(),
            seed: 42,
            log_every: 0,
            eval_every: 0,
            ..TrainConfig::default()
        };
        Trainer::new(&vrt, &pipeline, cfg).run().unwrap()
    };
    let (state1, m1) = run(1);
    let (state4, m4) = run(4);
    assert_eq!(m1.records.len(), 20);
    assert_eq!(m1.records.len(), m4.records.len());
    for (a, b) in m1.records.iter().zip(m4.records.iter()) {
        assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "loss @ step {}", a.step);
        assert_eq!(a.upd_frac.to_bits(), b.upd_frac.to_bits(), "upd_frac @ step {}", a.step);
        assert_eq!(a.gnorm.to_bits(), b.gnorm.to_bits(), "gnorm @ step {}", a.step);
    }
    assert_eq!(
        m1.final_dev_loss.unwrap().to_bits(),
        m4.final_dev_loss.unwrap().to_bits()
    );
    assert_eq!(state1.params.len(), state4.params.len());
    for (i, (a, b)) in state1.params.iter().zip(state4.params.iter()).enumerate() {
        assert_eq!(a, b, "param {i} diverged across thread counts");
    }
    for (a, b) in state1.opt.iter().zip(state4.opt.iter()) {
        assert_eq!(a, b);
    }
}

/// KV-cached decode steps on the packed-ternary serving path return
/// bitwise-identical logits — and therefore identical generations — at
/// 1 and 4 kernel threads, for both batch-1 GEMV and batched decode.
#[test]
fn decode_and_generation_are_bitwise_identical_across_thread_counts() {
    let engines: Vec<Engine> = [1usize, 4]
        .iter()
        .map(|&t| {
            let vrt = vrt_with(t);
            let state = vrt.init_state(42).unwrap();
            let pipeline = pipeline_for(&vrt);
            Engine::new(&vrt, &state, pipeline.tokenizer.clone(), false).unwrap()
        })
        .collect();
    assert_eq!(engines[0].decoder().threads(), 1);
    assert_eq!(engines[1].decoder().threads(), 4);

    // raw decode steps, batch 1: bitwise logit equality position by position
    let tokens = [1i32, 3, 5, 2, 7, 4];
    let mut caches: Vec<_> = engines.iter().map(|e| e.decoder().new_cache()).collect();
    for &t in &tokens {
        let l1 = engines[0].decoder().step(caches[0].as_mut(), t).unwrap();
        let l4 = engines[1].decoder().step(caches[1].as_mut(), t).unwrap();
        assert_eq!(l1.len(), l4.len());
        for (i, (a, b)) in l1.iter().zip(l4.iter()).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "token {t} logit {i}");
        }
    }

    // batched decode: advance 3 sequences together on each engine
    let batched: Vec<Vec<f32>> = engines
        .iter()
        .map(|e| {
            let dec = e.decoder();
            let mut cs: Vec<_> = (0..3).map(|_| dec.new_cache()).collect();
            let mut refs: Vec<&mut dyn dqt::runtime::DecoderCache> =
                cs.iter_mut().map(|c| &mut **c).collect();
            dec.step_batch(&mut refs[..], &[2, 4, 6]).unwrap()
        })
        .collect();
    assert_eq!(batched[0].len(), 3 * engines[0].decoder().vocab_size());
    assert_eq!(batched[0], batched[1]);

    // full generations (greedy and sampled) match token for token
    for params in [
        GenParams {
            max_new_tokens: 12,
            ..Default::default()
        },
        GenParams {
            max_new_tokens: 12,
            temperature: 1.3,
            top_k: 8,
            seed: 9,
            ..Default::default()
        },
    ] {
        let g1 = engines[0].generate("the cat sat", &params).unwrap();
        let g4 = engines[1].generate("the cat sat", &params).unwrap();
        assert_eq!(g1.token_ids, g4.token_ids);
        assert_eq!(g1.text, g4.text);
        assert_eq!(g1.finish, g4.finish);
    }
}

/// Eval (full-forward NLL) is bitwise thread-count-invariant too — the
/// path `repro eval` and the dev-loss probes take.
#[test]
fn eval_nll_is_bitwise_identical_across_thread_counts() {
    let vrt1 = vrt_with(1);
    let vrt4 = vrt_with(4);
    let state1 = vrt1.init_state(7).unwrap();
    let state4 = vrt4.init_state(7).unwrap();
    let m = vrt1.manifest();
    let shape = &m.tokens_shape;
    let v = m.variant.model.vocab_size as i32;
    let tokens: Vec<i32> = (0..shape[0] * shape[1]).map(|i| (i as i32 * 7 + 3) % v).collect();
    let (nll1, c1) = vrt1.eval_step(&state1, &tokens, false).unwrap();
    let (nll4, c4) = vrt4.eval_step(&state4, &tokens, false).unwrap();
    assert_eq!(nll1.to_bits(), nll4.to_bits());
    assert_eq!(c1, c4);
    let (t1, _) = vrt1.eval_step(&state1, &tokens, true).unwrap();
    let (t4, _) = vrt4.eval_step(&state4, &tokens, true).unwrap();
    assert_eq!(t1.to_bits(), t4.to_bits());
}

// ---------------------------------------------------------------------------
// Fast tier. The fast kernels give up the *cross-thread-count* bitwise
// guarantee (they reassociate sums), but keep two weaker contracts that
// these tests pin: (a) reruns at a FIXED thread count are bitwise
// identical — no hidden nondeterminism; (b) results track the exact tier
// within an f32-roundoff tolerance, so the training curve and greedy
// generations are interchangeable in practice.
// ---------------------------------------------------------------------------

fn train_run(vrt: &VariantRuntime) -> (dqt::runtime::State, dqt::train::RunMetrics) {
    let pipeline = pipeline_for(vrt);
    let cfg = TrainConfig {
        steps: 20,
        warmup_steps: 2,
        peak_lr: 2e-3,
        dataset: "tiny".into(),
        seed: 42,
        log_every: 0,
        eval_every: 0,
        ..TrainConfig::default()
    };
    Trainer::new(vrt, &pipeline, cfg).run().unwrap()
}

/// Fast-tier training is deterministic per thread count: rerunning the
/// same 20-step run with the same pool is bitwise identical, at 1 and at
/// 4 threads. (Cross-thread equality is deliberately NOT asserted — the
/// fast tier does not promise it.)
#[test]
fn fast_train_run_is_deterministic_at_fixed_thread_count() {
    for threads in [1usize, 4] {
        let (sa, ma) = train_run(&vrt_fast(threads));
        let (sb, mb) = train_run(&vrt_fast(threads));
        assert_eq!(ma.records.len(), 20);
        for (a, b) in ma.records.iter().zip(mb.records.iter()) {
            assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "fast t{threads} loss @ step {}", a.step);
            assert_eq!(a.gnorm.to_bits(), b.gnorm.to_bits(), "fast t{threads} gnorm @ step {}", a.step);
        }
        for (i, (a, b)) in sa.params.iter().zip(sb.params.iter()).enumerate() {
            assert_eq!(a, b, "fast t{threads} param {i} diverged on rerun");
        }
    }
}

/// The fast-tier 20-step loss curve stays within a loose tolerance of the
/// exact-tier curve. Differences come only from f32 reassociation (and
/// the rare stochastic-rounding flip it can induce), so per-step drift is
/// tiny relative to the losses themselves.
#[test]
fn fast_train_curve_tracks_exact_within_tolerance() {
    let (_, me) = train_run(&vrt_with(4));
    let (_, mf) = train_run(&vrt_fast(4));
    assert_eq!(me.records.len(), mf.records.len());
    for (a, b) in me.records.iter().zip(mf.records.iter()) {
        assert!(
            (a.loss - b.loss).abs() <= 0.1,
            "step {}: exact loss {} vs fast loss {}",
            a.step,
            a.loss,
            b.loss
        );
    }
}

/// Greedy generation under the fast tier emits the same token ids as the
/// exact tier (logit gaps at random init dwarf reassociation error), and
/// per-position decode logits agree within tolerance.
#[test]
fn fast_greedy_generation_matches_exact() {
    let engines: Vec<Engine> = [vrt_with(4), vrt_fast(4)]
        .iter()
        .map(|vrt| {
            let state = vrt.init_state(42).unwrap();
            let pipeline = pipeline_for(vrt);
            Engine::new(vrt, &state, pipeline.tokenizer.clone(), false).unwrap()
        })
        .collect();
    assert_eq!(engines[0].decoder().precision(), Precision::Exact);
    assert_eq!(engines[1].decoder().precision(), Precision::Fast);

    // raw decode steps: logits within f32-roundoff tolerance of exact
    let tokens = [1i32, 3, 5, 2, 7, 4];
    let mut caches: Vec<_> = engines.iter().map(|e| e.decoder().new_cache()).collect();
    for &t in &tokens {
        let le = engines[0].decoder().step(caches[0].as_mut(), t).unwrap();
        let lf = engines[1].decoder().step(caches[1].as_mut(), t).unwrap();
        assert_eq!(le.len(), lf.len());
        for (i, (a, b)) in le.iter().zip(lf.iter()).enumerate() {
            assert!(
                (a - b).abs() <= 1e-3 * (1.0 + a.abs()),
                "token {t} logit {i}: exact {a} vs fast {b}"
            );
        }
    }

    let params = GenParams {
        max_new_tokens: 12,
        ..Default::default()
    };
    let ge = engines[0].generate("the cat sat", &params).unwrap();
    let gf = engines[1].generate("the cat sat", &params).unwrap();
    assert_eq!(ge.token_ids, gf.token_ids, "greedy ids diverged across tiers");
    assert_eq!(ge.text, gf.text);
}
