//! Property-based tests (in-tree harness, `util::prop`) over the pure
//! substrates: codecs round-trip, SR is unbiased and support-correct, JSON
//! survives arbitrary values, the tokenizer round-trips arbitrary corpus
//! text, datasets cover every token, the CLI parser is total.

use dqt::data::corpus::Rng;
use dqt::data::dataset::Dataset;
use dqt::data::tokenizer::Tokenizer;
use dqt::quant::{self, bf16, fp8, intn, sr, ternary, Format, PackedTensor};
use dqt::util::json;
use dqt::util::prop::{check, gen};

#[test]
fn prop_ternary_pack_roundtrip() {
    check(
        200,
        |rng| {
            let n = 1 + rng.below(200);
            (0..n)
                .map(|_| (rng.below(3) as f32) - 1.0)
                .collect::<Vec<f32>>()
        },
        |v| {
            let p = ternary::pack(v).unwrap();
            ternary::unpack(&p, v.len()) == *v
        },
    );
}

#[test]
fn prop_intn_pack_roundtrip_all_widths() {
    check(
        200,
        |rng| {
            let bits = 2 + rng.below(7) as u32;
            let lo = -(1i32 << (bits - 1));
            let hi = (1i32 << (bits - 1)) - 1;
            let v = gen::vec_i32(rng, 300, lo, hi);
            (bits, v)
        },
        |(bits, v)| intn::unpack(&intn::pack(v, *bits).unwrap(), v.len(), *bits) == *v,
    );
}

#[test]
fn prop_packed_tensor_grid_roundtrip_all_formats() {
    // every grid format × unaligned lengths: pack → unpack is exact and the
    // packed size matches the registry's arithmetic
    check(
        300,
        |rng| {
            let n = 1 + rng.below(200);
            let bits = [1.58f64, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0][rng.below(8)];
            let fmt = Format::from_bits(bits);
            let (qn, qp) = fmt.grid_range();
            let s = 1.0 + 50.0 * rng.next_f64() as f32;
            let vals: Vec<f32> = (0..n)
                .map(|_| (qn + rng.below((qp - qn) as usize + 1) as f64) as f32 / s)
                .collect();
            (fmt, s, vals)
        },
        |(fmt, s, vals)| {
            let pt = PackedTensor::pack(vals, vec![vals.len()], *fmt, Some(*s)).unwrap();
            pt.packed_bytes() == fmt.packed_bytes(vals.len())
                && pt
                    .unpack()
                    .unwrap()
                    .iter()
                    .zip(vals.iter())
                    .all(|(a, b)| (a - b).abs() < 1e-6)
        },
    );
}

#[test]
fn prop_packed_tensor_dense_idempotent() {
    // dense formats: f32 is exact; bf16/fp8 are lossy but stable under a
    // second pack → unpack trip
    check(
        200,
        |rng| {
            let vals = gen::vec_f32(rng, 150, -300.0, 300.0);
            let fmt = [Format::F32, Format::Bf16, Format::Fp8E4m3][rng.below(3)];
            (fmt, vals)
        },
        |(fmt, vals)| {
            let pt = PackedTensor::pack(vals, vec![vals.len()], *fmt, None).unwrap();
            let once = pt.unpack().unwrap();
            if *fmt == Format::F32 && once != *vals {
                return false;
            }
            let pt2 = PackedTensor::pack(&once, vec![once.len()], *fmt, None).unwrap();
            pt2.bytes == pt.bytes && pt2.unpack().unwrap() == once
        },
    );
}

#[test]
fn prop_format_tag_roundtrip() {
    check(
        100,
        |rng| {
            [
                Format::F32,
                Format::Bf16,
                Format::Fp8E4m3,
                Format::Ternary2bit,
                Format::IntN(2 + rng.below(7) as u32),
            ][rng.below(5)]
        },
        |fmt| Format::from_tag(&fmt.tag()) == Ok(*fmt),
    );
}

#[test]
fn prop_sr_support_is_floor_or_ceil_clipped() {
    check(
        300,
        |rng| {
            let s = 0.5 + 100.0 * rng.next_f64() as f32;
            let x = gen::vec_f32(rng, 100, -3.0, 3.0);
            let seed = rng.below(1 << 30) as u32;
            (x, s, seed)
        },
        |(x, s, seed)| {
            let out = sr::sr_slice(x, *seed, 8.0, *s);
            x.iter().zip(out.iter()).all(|(&xi, &oi)| {
                let y = (xi * s).clamp(-128.0, 127.0);
                let k = oi * s;
                (k - k.round()).abs() < 1e-2
                    && k.round() >= y.floor() - 1.0
                    && k.round() <= y.ceil() + 1.0
            })
        },
    );
}

#[test]
fn prop_sr_mean_unbiased() {
    // for a fixed x repeated many times, mean(SR(x)) ≈ x
    check(
        10,
        |rng| 0.05 + 0.9 * rng.next_f64() as f32,
        |&x| {
            let xs = vec![x; 40_000];
            let out = sr::sr_slice(&xs, 123, 8.0, 1.0);
            let mean: f64 = out.iter().map(|&v| v as f64).sum::<f64>() / out.len() as f64;
            (mean - x as f64).abs() < 0.02
        },
    );
}

#[test]
fn prop_fp8_casts_idempotent_and_ordered() {
    check(
        300,
        |rng| gen::f32_in(rng, -500.0, 500.0),
        |&x| {
            for fmt in [fp8::Format::E4M3, fp8::Format::E5M2] {
                let y = fp8::cast(x, fmt);
                if fp8::cast(y, fmt) != y {
                    return false;
                }
                // sign preserved
                if x != 0.0 && y != 0.0 && x.signum() != y.signum() {
                    return false;
                }
            }
            true
        },
    );
}

#[test]
fn prop_bf16_idempotent_and_monotone() {
    check(
        200,
        |rng| {
            let a = gen::f32_in(rng, -1e6, 1e6);
            let b = gen::f32_in(rng, -1e6, 1e6);
            (a.min(b), a.max(b))
        },
        |&(lo, hi)| {
            let clo = bf16::cast(lo);
            let chi = bf16::cast(hi);
            bf16::cast(clo) == clo && bf16::cast(chi) == chi && clo <= chi
        },
    );
}

#[test]
fn prop_absmean_quantize_on_grid() {
    check(
        200,
        |rng| {
            let bits = *[1.58, 3.0, 4.0, 8.0]
                .iter()
                .nth(rng.below(4))
                .unwrap();
            (gen::vec_f32(rng, 200, -0.5, 0.5), bits)
        },
        |(w, bits)| {
            let s = quant::absmean_scale(w, *bits);
            let (qn, qp) = quant::qrange(*bits);
            quant::absmean_quantize(w, *bits, s).iter().all(|&v| {
                let k = (v * s) as f64;
                (k - k.round()).abs() < 1e-3 && k >= qn - 1e-3 && k <= qp + 1e-3
            })
        },
    );
}

#[test]
fn prop_json_roundtrip_strings() {
    check(
        300,
        |rng| {
            let mut s = gen::ascii_string(rng, 40);
            // sprinkle escapes + unicode
            if rng.below(2) == 0 {
                s.push('"');
                s.push('\\');
                s.push('\n');
                s.push('é');
                s.push('😀');
            }
            s
        },
        |s| {
            let v = json::Value::Str(s.clone());
            json::parse(&v.to_string()).unwrap().as_str() == Some(s.as_str())
        },
    );
}

#[test]
fn prop_json_roundtrip_nested() {
    fn gen_value(rng: &mut Rng, depth: usize) -> json::Value {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => json::Value::Null,
            1 => json::Value::Bool(rng.below(2) == 0),
            2 => json::Value::Num((rng.below(100000) as f64) / 16.0 - 100.0),
            3 => json::Value::Str(gen::ascii_string(rng, 12)),
            4 => json::Value::Arr((0..rng.below(4)).map(|_| gen_value(rng, depth - 1)).collect()),
            _ => json::Value::Obj(
                (0..rng.below(4))
                    .map(|i| (format!("k{i}"), gen_value(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
    check(
        200,
        |rng| gen_value(rng, 3),
        |v| {
            json::parse(&v.to_string()).unwrap() == *v
                && json::parse(&v.to_string_pretty()).unwrap() == *v
        },
    );
}

#[test]
fn prop_tokenizer_roundtrip_random_words() {
    // build a tokenizer on a fixed corpus, then round-trip arbitrary text
    // over the same alphabet
    let docs = vec![
        "aba bab abc cab bca ab ba ca".to_string(),
        "abc abc cab cab ab ab ab".to_string(),
    ];
    let tok = Tokenizer::train(&docs, 40);
    check(
        200,
        |rng| {
            let n = 1 + rng.below(10);
            (0..n)
                .map(|_| {
                    let len = 1 + rng.below(6);
                    (0..len)
                        .map(|_| ['a', 'b', 'c'][rng.below(3)])
                        .collect::<String>()
                })
                .collect::<Vec<_>>()
                .join(" ")
        },
        |text| tok.decode(&tok.encode(text)) == *text,
    );
}

#[test]
fn prop_dataset_covers_every_token_once() {
    check(
        50,
        |rng| {
            let n = 50 + rng.below(2000);
            let seq = 4 + rng.below(60);
            let stream: Vec<i32> = (0..n).map(|i| (i % 97) as i32 + 1).collect();
            (stream, seq, rng.below(1000) as u64)
        },
        |(stream, seq, seed)| {
            let ds = Dataset::from_stream(stream, *seq, 0.05, *seed);
            let mut got: Vec<i32> =
                ds.chunks.iter().copied().filter(|&t| t != 0).collect();
            let mut want = stream.clone();
            got.sort();
            want.sort();
            got == want
        },
    );
}

#[test]
fn prop_cli_parser_total_and_lossless_kv() {
    check(
        200,
        |rng| {
            let k = gen::ascii_string(rng, 8);
            let v = gen::ascii_string(rng, 8);
            (format!("k{k}"), v)
        },
        |(k, v)| {
            let raw = vec![format!("--{k}"), v.clone()];
            let args = dqt::util::cli::Args::parse(&raw).unwrap();
            args.get(k) == Some(v.as_str())
        },
    );
}

#[test]
fn prop_host_sr_matches_kernel_hash_stream() {
    // the rust hash must equal the python twin's (pinned golden values
    // regenerated by python/tests/test_interop.py)
    let golden: [(u32, u32, u32); 3] = [
        (0, 0, 0),
        (1, 2, 0),
        (12345, 67890, 0),
    ];
    for (ctr, seed, _) in golden {
        // determinism across calls is the property; cross-language equality
        // is asserted in the interop test with generated vectors
        assert_eq!(sr::hash_u32(ctr, seed), sr::hash_u32(ctr, seed));
    }
    check(
        100,
        |rng| (rng.below(1 << 30) as u32, rng.below(1 << 30) as u32),
        |&(c, s)| sr::uniform01(c, s) >= 0.0 && sr::uniform01(c, s) < 1.0,
    );
}
