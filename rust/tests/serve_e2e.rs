//! End-to-end serving tests: the ternary serving engine on the native
//! backend — KV-cache parity surfaces through the public API, generation
//! determinism, continuous-batching invariance (batched == solo), the
//! decode-free packed-weight contract, and the HTTP server round trip.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::Arc;

use dqt::config::{Mode, Precision, VariantSpec};
use dqt::data::Pipeline;
use dqt::kernels::Pool;
use dqt::runtime::{Decoder, NativeBackend, VariantRuntime};
use dqt::serve::{Engine, FinishReason, GenParams, Scheduler, Server};
use dqt::util::json;

fn ternary_spec() -> VariantSpec {
    VariantSpec::new("test", Mode::Dqt, 1.58)
}

fn engine_on(vrt: &VariantRuntime, seed: u32, ternary: bool) -> Engine {
    let state = vrt.init_state(seed).unwrap();
    let m = vrt.manifest();
    let pipeline = Pipeline::build(
        "tiny",
        1,
        m.variant.model.vocab_size,
        m.variant.model.max_seq_len,
    )
    .unwrap();
    Engine::new(vrt, &state, pipeline.tokenizer.clone(), ternary).unwrap()
}

fn engine_for(spec: &VariantSpec, seed: u32, ternary: bool) -> Engine {
    engine_on(&VariantRuntime::native(spec).unwrap(), seed, ternary)
}

/// Greedy generation is a pure function of (weights, prompt); sampled
/// generation is a pure function of (weights, prompt, seed).
#[test]
fn generation_is_deterministic_per_seed() {
    let engine = engine_for(&ternary_spec(), 42, false);
    let greedy = GenParams { max_new_tokens: 10, ..Default::default() };
    let a = engine.generate("the cat", &greedy).unwrap();
    let b = engine.generate("the cat", &greedy).unwrap();
    assert_eq!(a.token_ids, b.token_ids);
    assert_eq!(a.text, b.text);
    assert!(!a.token_ids.is_empty());
    assert!(a.prompt_tokens >= 1);

    let sampled = |seed| {
        let p = GenParams {
            max_new_tokens: 10,
            temperature: 1.5,
            seed,
            ..Default::default()
        };
        engine.generate("the cat", &p).unwrap().token_ids
    };
    assert_eq!(sampled(7), sampled(7));
    // across several seeds, at least two generations must differ
    let outs: Vec<_> = (0..4).map(sampled).collect();
    assert!(
        outs.iter().any(|o| o != &outs[0]),
        "4 seeds produced identical samples: {outs:?}"
    );
}

/// A near-uniform tiny model sampled at high temperature hits the
/// EOS/document-separator within a handful of seeds — the "EOS
/// termination" leg of the serving acceptance criteria.
#[test]
fn sampled_generation_terminates_at_eos() {
    let engine = engine_for(&ternary_spec(), 42, false);
    let mut eos_seen = false;
    for seed in 0..64 {
        let p = GenParams {
            max_new_tokens: 12,
            temperature: 1.5,
            seed,
            ..Default::default()
        };
        let g = engine.generate("the cat sat", &p).unwrap();
        assert!(!g.token_ids.is_empty());
        if g.finish == FinishReason::Eos {
            assert_eq!(*g.token_ids.last().unwrap(), engine.eos_id());
            eos_seen = true;
            break;
        }
    }
    assert!(eos_seen, "no EOS termination across 64 seeds");
}

/// Long prompts are left-truncated to fit the trained context, and
/// generation never exceeds it.
#[test]
fn prompt_truncation_and_cache_bounds() {
    let engine = engine_for(&ternary_spec(), 3, false);
    let long_prompt = "the cat sat on the mat and ran to the dog ".repeat(20);
    let g = engine
        .generate(&long_prompt, &GenParams { max_new_tokens: 100, ..Default::default() })
        .unwrap();
    assert!(g.prompt_tokens < engine.max_positions());
    assert!(g.prompt_tokens + g.token_ids.len() <= engine.max_positions() + 1);
    assert!(matches!(
        g.finish,
        FinishReason::CacheFull | FinishReason::Eos | FinishReason::Length
    ));
}

/// Continuous batching never changes a sequence's output: six requests
/// with mixed params forced through a width-3 batch (mid-flight
/// admission + eviction) must match their solo runs token for token.
#[test]
fn continuous_batching_matches_solo_generation() {
    let engine = Arc::new(engine_for(&ternary_spec(), 42, false));
    let sched = Scheduler::new(engine.clone(), 3);
    let reqs: Vec<(&str, GenParams)> = vec![
        ("the cat", GenParams { max_new_tokens: 8, ..Default::default() }),
        ("a dog sat", GenParams { max_new_tokens: 5, ..Default::default() }),
        (
            "the mat",
            GenParams { max_new_tokens: 9, temperature: 1.2, seed: 3, ..Default::default() },
        ),
        ("", GenParams { max_new_tokens: 6, ..Default::default() }),
        (
            "ran to",
            GenParams { max_new_tokens: 7, temperature: 0.8, top_k: 8, seed: 9, ..Default::default() },
        ),
        (
            "the cat sat on",
            GenParams { max_new_tokens: 10, temperature: 1.0, top_p: 0.9, seed: 4, ..Default::default() },
        ),
    ];
    let mut ids = Vec::new();
    for (prompt, params) in &reqs {
        ids.push(sched.submit(prompt, params.clone()));
    }
    sched.run_until_idle().unwrap();
    let mut finished = sched.take_finished();
    assert_eq!(finished.len(), reqs.len());
    finished.sort_by_key(|(id, _)| *id);
    for ((id, gen), (prompt, params)) in finished.iter().zip(reqs.iter()) {
        let solo = engine.generate(prompt, params).unwrap();
        assert_eq!(gen.token_ids, solo.token_ids, "request {id} ({prompt:?})");
        assert_eq!(gen.text, solo.text, "request {id}");
        assert_eq!(gen.finish, solo.finish, "request {id}");
        assert!(ids.contains(id));
    }
    let st = sched.stats();
    assert_eq!(st.completed, reqs.len() as u64);
    assert_eq!(st.peak_batch, 3);
    assert!(st.tokens_processed > 0 && st.tokens_generated > 0);
}

/// Batch invariance holds on the fast tier too: the `--precision fast`
/// kernels reassociate sums, but a sequence's logits may never depend on
/// which other sequences share its decode batch. Same six mixed requests
/// as above, forced through a width-3 batch on a fast pool, compared
/// token for token against their solo runs on the same engine.
#[test]
fn fast_precision_batching_matches_solo_generation() {
    let vrt = VariantRuntime::native_with_pool(
        &ternary_spec(),
        Arc::new(Pool::with_precision(4, Precision::Fast)),
    )
    .unwrap();
    let engine = Arc::new(engine_on(&vrt, 42, false));
    assert_eq!(engine.decoder().precision(), Precision::Fast);
    let sched = Scheduler::new(engine.clone(), 3);
    let reqs: Vec<(&str, GenParams)> = vec![
        ("the cat", GenParams { max_new_tokens: 8, ..Default::default() }),
        ("a dog sat", GenParams { max_new_tokens: 5, ..Default::default() }),
        (
            "the mat",
            GenParams { max_new_tokens: 9, temperature: 1.2, seed: 3, ..Default::default() },
        ),
        ("", GenParams { max_new_tokens: 6, ..Default::default() }),
        (
            "ran to",
            GenParams { max_new_tokens: 7, temperature: 0.8, top_k: 8, seed: 9, ..Default::default() },
        ),
        (
            "the cat sat on",
            GenParams { max_new_tokens: 10, temperature: 1.0, top_p: 0.9, seed: 4, ..Default::default() },
        ),
    ];
    for (prompt, params) in &reqs {
        sched.submit(prompt, params.clone());
    }
    sched.run_until_idle().unwrap();
    let mut finished = sched.take_finished();
    assert_eq!(finished.len(), reqs.len());
    finished.sort_by_key(|(id, _)| *id);
    for ((id, gen), (prompt, params)) in finished.iter().zip(reqs.iter()) {
        let solo = engine.generate(prompt, params).unwrap();
        assert_eq!(gen.token_ids, solo.token_ids, "fast request {id} ({prompt:?})");
        assert_eq!(gen.text, solo.text, "fast request {id}");
        assert_eq!(gen.finish, solo.finish, "fast request {id}");
    }
    assert_eq!(sched.stats().peak_batch, 3);
}

/// The serving path is decode-free for ternary variants: every projection
/// matmul runs off 2-bit packed codes, and resident serving weights are a
/// fraction of dense f32.
#[test]
fn ternary_serving_is_decode_free() {
    let spec = ternary_spec();
    let be = NativeBackend::new(&spec).unwrap();
    let vrt = VariantRuntime::native(&spec).unwrap();
    let mut state = vrt.init_state(1).unwrap();
    state.pack_grids(vrt.manifest()).unwrap();
    let dec = be.decoder_with(&state, false, true).unwrap();
    assert_eq!(dec.packed_projections(), dec.n_projections());
    assert!(dec.n_projections() > 0);
    let dense_bytes: usize = vrt
        .manifest()
        .params
        .iter()
        .filter(|p| !p.is_scale())
        .map(|p| p.numel() * 4)
        .sum();
    assert!(dec.weight_bytes() < dense_bytes);
    // §A.2: an int8-grid variant serves ternary when asked to
    let spec8 = VariantSpec::new("test", Mode::Dqt, 8.0);
    let be8 = NativeBackend::new(&spec8).unwrap();
    let vrt8 = VariantRuntime::native(&spec8).unwrap();
    let st8 = vrt8.init_state(1).unwrap();
    let dec8 = be8.decoder_with(&st8, true, true).unwrap();
    assert_eq!(dec8.packed_projections(), dec8.n_projections());
    let dec8_dense = be8.decoder_with(&st8, false, true).unwrap();
    assert_eq!(dec8_dense.packed_projections(), 0);
}

fn http_request(addr: SocketAddr, raw: &str) -> (u16, json::Value) {
    let mut s = TcpStream::connect(addr).unwrap();
    s.write_all(raw.as_bytes()).unwrap();
    let mut buf = Vec::new();
    s.read_to_end(&mut buf).unwrap(); // server closes the connection
    let text = String::from_utf8_lossy(&buf).into_owned();
    let code: u16 = text
        .split_whitespace()
        .nth(1)
        .expect("status line")
        .parse()
        .expect("status code");
    let body = text.split("\r\n\r\n").nth(1).expect("body").to_string();
    (code, json::parse(&body).expect("JSON body"))
}

fn post_generate(addr: SocketAddr, body: &str) -> (u16, json::Value) {
    let raw = format!(
        "POST /v1/generate HTTP/1.1\r\nHost: x\r\nContent-Type: application/json\r\nContent-Length: {}\r\n\r\n{}",
        body.len(),
        body
    );
    http_request(addr, &raw)
}

/// Full HTTP round trip against a live server on an ephemeral port:
/// healthz, generate (deterministic across identical requests), stats,
/// input validation, unknown routes.
#[test]
fn http_server_round_trip() {
    let engine = engine_for(&ternary_spec(), 42, false);
    let server = Server::bind("127.0.0.1:0", engine, 4).unwrap();
    let addr = server.local_addr().unwrap();
    std::thread::spawn(move || server.run().unwrap());

    let (code, health) = http_request(addr, "GET /healthz HTTP/1.1\r\nHost: x\r\n\r\n");
    assert_eq!(code, 200);
    assert_eq!(health.get("status").and_then(|s| s.as_str()), Some("ok"));
    assert!(health.get("max_positions").and_then(|v| v.as_usize()).unwrap() > 0);
    assert_eq!(
        health.get("packed_projections").and_then(|v| v.as_usize()),
        health.get("n_projections").and_then(|v| v.as_usize()),
        "ternary serving must be fully packed"
    );

    let body = r#"{"prompt": "the cat", "max_new_tokens": 8}"#;
    let (code, a) = post_generate(addr, body);
    assert_eq!(code, 200, "{a:?}");
    let gen_tokens = a.get("gen_tokens").and_then(|v| v.as_usize()).unwrap();
    assert!(gen_tokens > 0, "{a:?}");
    assert_eq!(
        a.get("token_ids").and_then(|v| v.as_arr()).unwrap().len(),
        gen_tokens
    );
    assert!(a.get("prompt_tokens").and_then(|v| v.as_usize()).unwrap() >= 1);
    let finish = a.get("finish_reason").and_then(|v| v.as_str()).unwrap();
    assert!(["eos", "length", "cache_full"].contains(&finish), "{finish}");
    // greedy requests are deterministic across connections
    let (_, b) = post_generate(addr, body);
    assert_eq!(a.get("text"), b.get("text"));
    assert_eq!(a.get("token_ids"), b.get("token_ids"));

    let (code, stats) = http_request(addr, "GET /v1/stats HTTP/1.1\r\nHost: x\r\n\r\n");
    assert_eq!(code, 200);
    assert!(stats.get("completed").and_then(|v| v.as_u64()).unwrap() >= 2);
    assert!(stats.get("tokens_generated").and_then(|v| v.as_u64()).unwrap() > 0);
    // configuration attribution: kernel threads + cumulative decode rate
    assert!(stats.get("threads").and_then(|v| v.as_usize()).unwrap() >= 1);
    assert!(stats.get("decode_tokens_per_sec").and_then(|v| v.as_f64()).unwrap() > 0.0);
    assert_eq!(
        health.get("threads").and_then(|v| v.as_usize()),
        stats.get("threads").and_then(|v| v.as_usize())
    );
    // both endpoints attribute the numeric tier; default engine is exact
    assert_eq!(health.get("precision").and_then(|v| v.as_str()), Some("exact"));
    assert_eq!(
        health.get("precision").and_then(|v| v.as_str()),
        stats.get("precision").and_then(|v| v.as_str())
    );

    let (code, err) = post_generate(addr, "{\"no_prompt\": 1}");
    assert_eq!(code, 400);
    assert!(err.get("error").is_some());
    let (code, _) = post_generate(addr, "not json at all");
    assert_eq!(code, 400);
    let (code, _) = http_request(addr, "GET /nope HTTP/1.1\r\nHost: x\r\n\r\n");
    assert_eq!(code, 404);
}

/// Raw (non-JSON) request helper for the text-format `/metrics` endpoint:
/// returns (status, full head, body).
fn http_get_text(addr: SocketAddr, path: &str) -> (u16, String, String) {
    let mut s = TcpStream::connect(addr).unwrap();
    write!(s, "GET {path} HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
    let mut buf = Vec::new();
    s.read_to_end(&mut buf).unwrap();
    let text = String::from_utf8_lossy(&buf).into_owned();
    let code: u16 = text
        .split_whitespace()
        .nth(1)
        .expect("status line")
        .parse()
        .expect("status code");
    let (head, body) = text.split_once("\r\n\r\n").expect("header/body split");
    (code, head.to_string(), body.to_string())
}

/// The value of `name` (an unlabeled series) in rendered exposition text.
fn metric_value(body: &str, name: &str) -> Option<f64> {
    body.lines()
        .find(|l| l.starts_with(name) && l.as_bytes().get(name.len()) == Some(&b' '))
        .and_then(|l| l.rsplit(' ').next())
        .and_then(|v| v.parse().ok())
}

/// `GET /metrics` serves valid Prometheus text whose decode counters move
/// once a generation completes — the serving half of the observability
/// contract (docs/OBSERVABILITY.md).
#[test]
fn metrics_endpoint_reflects_decode_activity() {
    let engine = engine_for(&ternary_spec(), 42, false);
    let server = Server::bind("127.0.0.1:0", engine, 4).unwrap();
    let addr = server.local_addr().unwrap();
    std::thread::spawn(move || server.run().unwrap());

    let (code, head, body) = http_get_text(addr, "/metrics");
    assert_eq!(code, 200);
    assert!(head.contains("text/plain; version=0.0.4"), "{head}");
    // every non-comment line is `series value` with a finite float value
    for line in body.lines().filter(|l| !l.is_empty() && !l.starts_with('#')) {
        let (series, value) = line.rsplit_once(' ').expect("series + value");
        assert!(series.starts_with("dqt_serve_"), "foreign series: {line}");
        let v: f64 = value.parse().unwrap_or_else(|_| panic!("bad value: {line}"));
        assert!(v.is_finite(), "{line}");
    }
    assert!(body.contains("# TYPE dqt_serve_requests_total counter"), "{body}");
    assert!(body.contains("# TYPE dqt_serve_ttft_seconds histogram"), "{body}");
    assert_eq!(metric_value(&body, "dqt_serve_tokens_generated_total"), Some(0.0));

    let (code, _) = post_generate(addr, r#"{"prompt": "the cat", "max_new_tokens": 6}"#);
    assert_eq!(code, 200);

    let (_, _, body) = http_get_text(addr, "/metrics");
    assert!(metric_value(&body, "dqt_serve_tokens_generated_total").unwrap() > 0.0);
    assert!(metric_value(&body, "dqt_serve_decode_steps_total").unwrap() > 0.0);
    assert_eq!(metric_value(&body, "dqt_serve_requests_total"), Some(1.0));
    assert_eq!(metric_value(&body, "dqt_serve_completed_total"), Some(1.0));
    assert_eq!(metric_value(&body, "dqt_serve_ttft_seconds_count"), Some(1.0));
    assert_eq!(metric_value(&body, "dqt_serve_request_seconds_count"), Some(1.0));
    // the first scrape plus the generate: exactly two 200s at render time
    assert!(
        body.contains("dqt_serve_http_responses_total{code=\"200\"} 2\n"),
        "{body}"
    );
}
