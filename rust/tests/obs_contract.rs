//! The observability contract: every metric name a build can export must
//! be documented in `docs/OBSERVABILITY.md`. The metric registries are
//! code (`serve::ServeMetrics`, `obs::TrainObs`); the doc is the contract
//! scrapers and dashboards are written against — this test keeps the two
//! from drifting.

use std::collections::BTreeSet;
use std::path::Path;

use dqt::obs::TrainObs;
use dqt::serve::ServeMetrics;

fn doc_text() -> String {
    let path = Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("docs")
        .join("OBSERVABILITY.md");
    std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("reading {}: {e}", path.display()))
}

/// Every name either bundle registers, deduplicated — the full exported
/// surface of `/metrics` on serve, train and dist processes. The quant
/// families only register once a run reveals its grid layers, so one
/// synthetic layer stands in for the manifest here.
fn all_metric_names() -> BTreeSet<String> {
    let mut names = BTreeSet::new();
    names.extend(ServeMetrics::new().registry().metric_names());
    let train = TrainObs::new();
    train.init_quant(&[("layers.0.wq".to_string(), 1)]);
    names.extend(train.registry().metric_names());
    names
}

#[test]
fn every_exported_metric_is_documented() {
    let doc = doc_text();
    let names = all_metric_names();
    assert!(names.len() >= 35, "registries shrank suspiciously: {names:?}");
    let missing: Vec<&String> = names.iter().filter(|n| !doc.contains(n.as_str())).collect();
    assert!(
        missing.is_empty(),
        "metrics exported but not documented in docs/OBSERVABILITY.md: {missing:?}"
    );
}

#[test]
fn metric_names_follow_the_naming_convention() {
    for name in all_metric_names() {
        assert!(
            name.starts_with("dqt_serve_")
                || name.starts_with("dqt_train_")
                || name.starts_with("dqt_dist_"),
            "metric {name} is outside the dqt_(serve|train|dist)_ namespaces"
        );
        assert!(
            name.chars().all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '_'),
            "metric {name} is not lower_snake_case"
        );
    }
}

/// The all-reduce traffic series split by gradient wire format: all
/// three `format` label values must exist from process start (zeroed
/// series, so dashboards can rate() them without gaps) and render with
/// the label attached.
#[test]
fn allreduce_series_carry_the_format_label() {
    let obs = TrainObs::new();
    obs.on_allreduce("ternary", 512, std::time::Duration::from_millis(2));
    let text = obs.registry().render();
    for f in dqt::obs::train::GRAD_FORMATS {
        for family in [
            "dqt_dist_allreduce_bytes_total",
            "dqt_dist_allreduce_seconds_total",
        ] {
            assert!(
                text.contains(&format!("{family}{{format=\"{f}\"}}")),
                "missing series {family}{{format=\"{f}\"}} in:\n{text}"
            );
        }
    }
    assert!(
        text.contains("dqt_dist_allreduce_bytes_total{format=\"ternary\"} 512\n"),
        "{text}"
    );
    // and the doc names the label so the contract covers it
    assert!(
        doc_text().contains("`format`"),
        "docs/OBSERVABILITY.md must document the format label"
    );
}

/// Per-layer contract, from a real 20-step native run on the test
/// preset: every quant series' `layer` label value is a manifest grid
/// param name (and every grid param gets a series), and the run's
/// `quant_health.json` carries the full documented schema with nonzero
/// flip counts.
#[test]
fn quant_health_layer_labels_and_json_schema_from_a_native_run() {
    use dqt::config::{Mode, TrainConfig, VariantSpec};
    use dqt::data::Pipeline;
    use dqt::runtime::VariantRuntime;
    use dqt::train::Trainer;

    let spec = VariantSpec::new("test", Mode::Dqt, 1.58);
    let cfg = spec.model_config().unwrap();
    let vrt = VariantRuntime::native(&spec).unwrap();
    let pipeline = Pipeline::build("tiny", 42, cfg.vocab_size, cfg.max_seq_len).unwrap();
    let tcfg = TrainConfig {
        steps: 20,
        warmup_steps: 2,
        peak_lr: 1e-2,
        dataset: "tiny".into(),
        seed: 42,
        log_every: 0,
        eval_every: 0,
        ..TrainConfig::default()
    };
    let mut tr = Trainer::new(&vrt, &pipeline, tcfg);
    tr.run().unwrap();

    let expected = vrt.quant_layers();
    assert!(!expected.is_empty(), "the test preset must have grid layers");
    let text = tr.obs.registry().render();
    for (name, _) in &expected {
        assert!(
            text.contains(&format!("dqt_train_quant_flips_total{{layer=\"{name}\"}}")),
            "missing per-layer series for {name} in:\n{text}"
        );
    }

    let dir = std::env::temp_dir().join("dqt_obs_contract_quant_health");
    std::fs::remove_dir_all(&dir).ok();
    tr.obs.save_quant_health(&dir).unwrap();
    let raw = std::fs::read_to_string(dir.join("quant_health.json")).unwrap();
    let v = dqt::util::json::parse(&raw).unwrap();
    assert_eq!(v.get("version").and_then(|x| x.as_u64()), Some(1));
    assert_eq!(v.get("steps").and_then(|x| x.as_u64()), Some(20));
    assert!(v.get("anomalies").and_then(|x| x.as_arr()).is_some());
    let layers = v.get("layers").and_then(|x| x.as_arr()).unwrap();
    assert_eq!(layers.len(), expected.len());
    let fields = [
        "name",
        "weights",
        "steps",
        "flips_total",
        "flip_rate",
        "last_flips",
        "net_upd_grid_steps",
        "abs_upd_grid_steps",
        "occupancy",
        "scale",
        "scale_drift",
        "saturation",
        "zero_frac",
        "oscillation",
        "grad_norm",
    ];
    for (l, (name, weights)) in layers.iter().zip(&expected) {
        for f in fields {
            assert!(l.get(f).is_some(), "layer {name} missing field {f}");
        }
        assert_eq!(l.get("name").and_then(|x| x.as_str()), Some(name.as_str()));
        assert_eq!(l.get("weights").and_then(|x| x.as_u64()), Some(*weights));
        assert_eq!(l.get("steps").and_then(|x| x.as_u64()), Some(20));
        let occ: u64 = l
            .get("occupancy")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|c| c.as_u64().unwrap())
            .sum();
        assert_eq!(occ, *weights, "{name}: occupancy must sum to the weight count");
    }
    let flips: u64 = layers
        .iter()
        .map(|l| l.get("flips_total").unwrap().as_u64().unwrap())
        .sum();
    assert!(flips > 0, "SR moved no weights in 20 steps — recording is broken");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn documented_streaming_tags_match_the_wire() {
    // the doc's wire table pins the frame tags and version; a tag or
    // version bump must update the table
    let doc = doc_text();
    for needle in ["| `1` |", "| `2` |", "| `3` |", "| `4` |"] {
        assert!(doc.contains(needle), "wire table row {needle} missing");
    }
    assert!(
        doc.contains(&format!(
            "protocol version {}",
            dqt::obs::stream::STREAM_PROTOCOL_VERSION
        )),
        "doc must state the current stream protocol version"
    );
}
