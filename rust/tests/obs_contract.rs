//! The observability contract: every metric name a build can export must
//! be documented in `docs/OBSERVABILITY.md`. The metric registries are
//! code (`serve::ServeMetrics`, `obs::TrainObs`); the doc is the contract
//! scrapers and dashboards are written against — this test keeps the two
//! from drifting.

use std::collections::BTreeSet;
use std::path::Path;

use dqt::obs::TrainObs;
use dqt::serve::ServeMetrics;

fn doc_text() -> String {
    let path = Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("docs")
        .join("OBSERVABILITY.md");
    std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("reading {}: {e}", path.display()))
}

/// Every name either bundle registers, deduplicated — the full exported
/// surface of `/metrics` on serve, train and dist processes.
fn all_metric_names() -> BTreeSet<String> {
    let mut names = BTreeSet::new();
    names.extend(ServeMetrics::new().registry().metric_names());
    names.extend(TrainObs::new().registry().metric_names());
    names
}

#[test]
fn every_exported_metric_is_documented() {
    let doc = doc_text();
    let names = all_metric_names();
    assert!(names.len() >= 25, "registries shrank suspiciously: {names:?}");
    let missing: Vec<&String> = names.iter().filter(|n| !doc.contains(n.as_str())).collect();
    assert!(
        missing.is_empty(),
        "metrics exported but not documented in docs/OBSERVABILITY.md: {missing:?}"
    );
}

#[test]
fn metric_names_follow_the_naming_convention() {
    for name in all_metric_names() {
        assert!(
            name.starts_with("dqt_serve_")
                || name.starts_with("dqt_train_")
                || name.starts_with("dqt_dist_"),
            "metric {name} is outside the dqt_(serve|train|dist)_ namespaces"
        );
        assert!(
            name.chars().all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '_'),
            "metric {name} is not lower_snake_case"
        );
    }
}

#[test]
fn documented_streaming_tags_match_the_wire() {
    // the doc's wire table pins the frame tags and version; a tag or
    // version bump must update the table
    let doc = doc_text();
    for needle in ["| `1` |", "| `2` |", "| `3` |"] {
        assert!(doc.contains(needle), "wire table row {needle} missing");
    }
    assert!(
        doc.contains(&format!(
            "protocol version {}",
            dqt::obs::stream::STREAM_PROTOCOL_VERSION
        )),
        "doc must state the current stream protocol version"
    );
}
