//! The observability contract: every metric name a build can export must
//! be documented in `docs/OBSERVABILITY.md`. The metric registries are
//! code (`serve::ServeMetrics`, `obs::TrainObs`); the doc is the contract
//! scrapers and dashboards are written against — this test keeps the two
//! from drifting.

use std::collections::BTreeSet;
use std::path::Path;

use dqt::obs::TrainObs;
use dqt::serve::ServeMetrics;

fn doc_text() -> String {
    let path = Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("docs")
        .join("OBSERVABILITY.md");
    std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("reading {}: {e}", path.display()))
}

/// Every name either bundle registers, deduplicated — the full exported
/// surface of `/metrics` on serve, train and dist processes.
fn all_metric_names() -> BTreeSet<String> {
    let mut names = BTreeSet::new();
    names.extend(ServeMetrics::new().registry().metric_names());
    names.extend(TrainObs::new().registry().metric_names());
    names
}

#[test]
fn every_exported_metric_is_documented() {
    let doc = doc_text();
    let names = all_metric_names();
    assert!(names.len() >= 25, "registries shrank suspiciously: {names:?}");
    let missing: Vec<&String> = names.iter().filter(|n| !doc.contains(n.as_str())).collect();
    assert!(
        missing.is_empty(),
        "metrics exported but not documented in docs/OBSERVABILITY.md: {missing:?}"
    );
}

#[test]
fn metric_names_follow_the_naming_convention() {
    for name in all_metric_names() {
        assert!(
            name.starts_with("dqt_serve_")
                || name.starts_with("dqt_train_")
                || name.starts_with("dqt_dist_"),
            "metric {name} is outside the dqt_(serve|train|dist)_ namespaces"
        );
        assert!(
            name.chars().all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '_'),
            "metric {name} is not lower_snake_case"
        );
    }
}

/// The all-reduce traffic series split by gradient wire format: all
/// three `format` label values must exist from process start (zeroed
/// series, so dashboards can rate() them without gaps) and render with
/// the label attached.
#[test]
fn allreduce_series_carry_the_format_label() {
    let obs = TrainObs::new();
    obs.on_allreduce("ternary", 512, std::time::Duration::from_millis(2));
    let text = obs.registry().render();
    for f in dqt::obs::train::GRAD_FORMATS {
        for family in [
            "dqt_dist_allreduce_bytes_total",
            "dqt_dist_allreduce_seconds_total",
        ] {
            assert!(
                text.contains(&format!("{family}{{format=\"{f}\"}}")),
                "missing series {family}{{format=\"{f}\"}} in:\n{text}"
            );
        }
    }
    assert!(
        text.contains("dqt_dist_allreduce_bytes_total{format=\"ternary\"} 512\n"),
        "{text}"
    );
    // and the doc names the label so the contract covers it
    assert!(
        doc_text().contains("`format`"),
        "docs/OBSERVABILITY.md must document the format label"
    );
}

#[test]
fn documented_streaming_tags_match_the_wire() {
    // the doc's wire table pins the frame tags and version; a tag or
    // version bump must update the table
    let doc = doc_text();
    for needle in ["| `1` |", "| `2` |", "| `3` |"] {
        assert!(doc.contains(needle), "wire table row {needle} missing");
    }
    assert!(
        doc.contains(&format!(
            "protocol version {}",
            dqt::obs::stream::STREAM_PROTOCOL_VERSION
        )),
        "doc must state the current stream protocol version"
    );
}
