//! The tracing contract: every span name the tracer can emit must be
//! documented in `docs/OBSERVABILITY.md`. The span vocabulary is code
//! (`obs::trace::names`); the doc's span-name table is the contract
//! `check_trace.py`, Perfetto queries and profiling notes are written
//! against — this test keeps the two from drifting.

use std::collections::BTreeSet;
use std::path::Path;

use dqt::obs::trace::names;

fn doc_text() -> String {
    let path = Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("docs")
        .join("OBSERVABILITY.md");
    std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("reading {}: {e}", path.display()))
}

#[test]
fn every_span_name_is_documented() {
    let doc = doc_text();
    assert!(
        names::ALL.len() >= 19,
        "span vocabulary shrank suspiciously: {:?}",
        names::ALL
    );
    let missing: Vec<&&str> = names::ALL
        .iter()
        .filter(|n| !doc.contains(&format!("`{}`", **n)))
        .collect();
    assert!(
        missing.is_empty(),
        "span names emitted but not documented in docs/OBSERVABILITY.md: {missing:?}"
    );
}

#[test]
fn span_names_follow_the_naming_convention() {
    for name in names::ALL {
        assert!(
            name.contains('.'),
            "span name {name} must be subsystem.phase dotted"
        );
        assert!(
            name.chars()
                .all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '_' || c == '.'),
            "span name {name} is not lower.dot_case"
        );
        assert!(
            !name.starts_with('.') && !name.ends_with('.') && !name.contains(".."),
            "span name {name} has empty dotted segments"
        );
        let subsystem = name.split('.').next().unwrap();
        assert!(
            matches!(subsystem, "train" | "fwd" | "dist" | "serve" | "kernel"),
            "span name {name} is outside the known subsystems"
        );
    }
}

#[test]
fn span_vocabulary_has_no_duplicates() {
    let unique: BTreeSet<&&str> = names::ALL.iter().collect();
    assert_eq!(
        unique.len(),
        names::ALL.len(),
        "duplicate entries in obs::trace::names::ALL"
    );
}
