//! Integration tests over real AOT artifacts (require `make artifacts`).
//!
//! These exercise the full L3→PJRT→HLO path: init, train steps, loss
//! decrease, grid invariants, determinism, checkpoint round-trips,
//! ternary inference and the eval harness — everything an experiment run
//! depends on, at `test`-config scale so the suite stays fast.

use std::path::PathBuf;

use dqt::data::corpus::CorpusSpec;
use dqt::data::Pipeline;
use dqt::quant;
use dqt::runtime::{Runtime, State, VariantRuntime};
use dqt::train::{checkpoint, step_seed, CosineSchedule, Trainer};
use dqt::config::TrainConfig;

fn artifacts_root() -> PathBuf {
    dqt::default_artifacts_root()
}

fn have_artifacts() -> bool {
    artifacts_root().join("test-dqt-b1p58/manifest.json").is_file()
}

// PjRtClient wraps an Rc (not Send/Sync), so each test thread gets its own
// client via thread_local.
thread_local! {
    static RT: std::rc::Rc<Runtime> =
        std::rc::Rc::new(Runtime::cpu().expect("pjrt cpu client"));
}

fn with_runtime<T>(f: impl FnOnce(&Runtime) -> T) -> T {
    RT.with(|rt| f(rt))
}

fn pipeline_for(vrt: &VariantRuntime) -> Pipeline {
    let m = vrt.manifest();
    Pipeline::build(
        "tiny",
        1,
        m.variant.model.vocab_size,
        m.variant.model.max_seq_len,
    )
    .unwrap()
}

fn train_n(vrt: &VariantRuntime, n: u64, seed: u64) -> (State, Vec<f32>) {
    let pipeline = pipeline_for(vrt);
    let m = vrt.manifest();
    let loader = pipeline.loader(m.variant.model.batch_size, n, seed);
    let sched = CosineSchedule::new(1e-3, 1e-5, 2, n);
    let mut state = vrt.init_state(seed as u32).unwrap();
    let mut losses = Vec::new();
    while let Some(b) = loader.next() {
        let lr = sched.lr(b.step) as f32;
        let (s2, met) = vrt
            .train_step(state, &b.tokens, step_seed(seed, b.step), lr)
            .unwrap();
        state = s2;
        losses.push(met.loss);
    }
    (state, losses)
}

#[test]
fn init_state_matches_manifest_shapes() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let vrt = with_runtime(|rt| VariantRuntime::load(rt, artifacts_root(), "test-dqt-b1p58")).unwrap();
    let m = vrt.manifest();
    let state = vrt.init_state(42).unwrap();
    assert_eq!(state.params.len(), m.params.len());
    assert_eq!(state.opt.len(), m.opt_state.len());
    for (meta, vals) in m.params.iter().zip(&state.params) {
        assert_eq!(vals.len(), meta.numel(), "{}", meta.name);
    }
    assert_eq!(state.step(), 0.0);
    // grid invariant at init
    for (i, meta) in m.params.iter().enumerate() {
        if meta.is_grid() {
            let s = state.params[i + 1][0];
            for &v in &state.params[i] {
                let k = v * s;
                assert!((k - k.round()).abs() < 1e-3, "{} off grid", meta.name);
                assert!((-1.0 - 1e-3..=1.0 + 1e-3).contains(&k));
            }
        }
    }
}

#[test]
fn ternary_training_decreases_loss_and_stays_on_grid() {
    if !have_artifacts() {
        return;
    }
    let vrt = with_runtime(|rt| VariantRuntime::load(rt, artifacts_root(), "test-dqt-b1p58")).unwrap();
    let (state, losses) = train_n(&vrt, 25, 42);
    let head: f32 = losses[..5].iter().sum::<f32>() / 5.0;
    let tail: f32 = losses[losses.len() - 5..].iter().sum::<f32>() / 5.0;
    assert!(tail < head, "loss did not decrease: {head} -> {tail}");
    let m = vrt.manifest();
    for (i, meta) in m.params.iter().enumerate() {
        if meta.is_grid() {
            let s = state.params[i + 1][0];
            for &v in &state.params[i] {
                let k = v * s;
                assert!((k - k.round()).abs() < 1e-3);
            }
        }
    }
    assert_eq!(state.step(), 25.0);
}

#[test]
fn training_is_deterministic_and_seed_sensitive() {
    if !have_artifacts() {
        return;
    }
    let vrt = with_runtime(|rt| VariantRuntime::load(rt, artifacts_root(), "test-dqt-b1p58")).unwrap();
    let (s1, l1) = train_n(&vrt, 6, 7);
    let (s2, l2) = train_n(&vrt, 6, 7);
    let (_, l3) = train_n(&vrt, 6, 8);
    assert_eq!(l1, l2);
    assert_ne!(l1, l3);
    for (a, b) in s1.params.iter().zip(s2.params.iter()) {
        assert_eq!(a, b);
    }
}

#[test]
fn all_core_modes_train() {
    if !have_artifacts() {
        return;
    }
    for variant in ["test-fp32", "test-bitnet158", "test-dqt-b8"] {
        let vrt = with_runtime(|rt| VariantRuntime::load(rt, artifacts_root(), variant)).unwrap();
        let (_, losses) = train_n(&vrt, 16, 42);
        assert!(losses.iter().all(|l| l.is_finite()), "{variant}");
        // compare head/tail window means — single batches are noisy at
        // test-config scale
        let head: f32 = losses[..4].iter().sum::<f32>() / 4.0;
        let tail: f32 = losses[losses.len() - 4..].iter().sum::<f32>() / 4.0;
        assert!(tail < head, "{variant}: {head} -> {tail}");
    }
}

#[test]
fn trainer_with_dev_eval_and_metrics() {
    if !have_artifacts() {
        return;
    }
    let vrt = with_runtime(|rt| VariantRuntime::load(rt, artifacts_root(), "test-dqt-b1p58")).unwrap();
    let pipeline = pipeline_for(&vrt);
    let cfg = TrainConfig {
        steps: 12,
        warmup_steps: 2,
        peak_lr: 1e-3,
        dataset: "tiny".into(),
        eval_every: 5,
        log_every: 0,
        ..TrainConfig::default()
    };
    let (state, metrics) = Trainer::new(&vrt, &pipeline, cfg).run().unwrap();
    assert_eq!(metrics.records.len(), 12);
    assert!(!metrics.dev_losses.is_empty());
    assert!(metrics.final_dev_loss.unwrap().is_finite());
    assert!(metrics.peak_upd_frac().unwrap() > 0.0);
    assert_eq!(state.step(), 12.0);
}

#[test]
fn checkpoint_roundtrip_and_resume() {
    if !have_artifacts() {
        return;
    }
    let vrt = with_runtime(|rt| VariantRuntime::load(rt, artifacts_root(), "test-dqt-b1p58")).unwrap();
    let m = vrt.manifest();
    let (state, _) = train_n(&vrt, 8, 42);
    let dir = std::env::temp_dir().join("dqt_it_ckpt");
    let path = dir.join("model.dqt");
    checkpoint::save(&path, m, &state, checkpoint::Codec::F32, true).unwrap();
    let loaded = checkpoint::load(&path, m).unwrap();
    // ternary grid packing is lossless
    for (i, (a, b)) in state.params.iter().zip(loaded.params.iter()).enumerate() {
        for (x, y) in a.iter().zip(b.iter()) {
            assert!((x - y).abs() < 1e-6, "param {i} ({})", m.params[i].name);
        }
    }
    assert_eq!(loaded.step(), 8.0);
    // resumed training continues identically to a state held in memory
    let pipeline = pipeline_for(&vrt);
    let batch = pipeline.loader(m.variant.model.batch_size, 1, 99).next().unwrap();
    let (_, met_mem) = vrt
        .train_step(state, &batch.tokens, step_seed(99, 0), 1e-3)
        .unwrap();
    let (_, met_load) = vrt
        .train_step(loaded, &batch.tokens, step_seed(99, 0), 1e-3)
        .unwrap();
    assert_eq!(met_mem.loss, met_load.loss);
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn packed_checkpoint_sizes_reflect_bit_widths() {
    if !have_artifacts() {
        return;
    }
    let tern = with_runtime(|rt| VariantRuntime::load(rt, artifacts_root(), "test-dqt-b1p58")).unwrap();
    let int8 = with_runtime(|rt| VariantRuntime::load(rt, artifacts_root(), "test-dqt-b8")).unwrap();
    let t_bytes = checkpoint::packed_param_bytes(tern.manifest());
    let i_bytes = checkpoint::packed_param_bytes(int8.manifest());
    let f_bytes = tern.manifest().total_param_values() * 4;
    assert!(t_bytes < i_bytes, "{t_bytes} !< {i_bytes}");
    assert!(i_bytes < f_bytes);
    // the quantized share of the test model is ~63%; packing it at 2 bits
    // must save well over a third overall
    assert!((t_bytes as f64) < f_bytes as f64 * 0.7);
}

#[test]
fn eval_and_ternary_inference_paths() {
    if !have_artifacts() {
        return;
    }
    let vrt = with_runtime(|rt| VariantRuntime::load(rt, artifacts_root(), "test-dqt-b8")).unwrap();
    assert!(vrt.has_ternary_inference());
    let (state, _) = train_n(&vrt, 10, 42);
    let pipeline = pipeline_for(&vrt);
    let ppl8 = dqt::eval::perplexity(&vrt, &state, &pipeline, false).unwrap();
    let ppl3 = dqt::eval::perplexity(&vrt, &state, &pipeline, true).unwrap();
    assert!(ppl8.is_finite() && ppl8 > 1.0);
    assert!(ppl3.is_finite() && ppl3 > 1.0);
    assert_ne!(ppl8, ppl3); // ternary projection must change the model
}

#[test]
fn zero_shot_suite_runs_end_to_end() {
    if !have_artifacts() {
        return;
    }
    let vrt = with_runtime(|rt| VariantRuntime::load(rt, artifacts_root(), "test-dqt-b1p58")).unwrap();
    let (state, _) = train_n(&vrt, 10, 42);
    let pipeline = pipeline_for(&vrt);
    let spec = CorpusSpec::tiny(1);
    let r = dqt::eval::evaluate(&vrt, &state, &pipeline, &spec, 12, false, 3).unwrap();
    assert_eq!(r.task_acc.len(), 4);
    for (name, acc) in &r.task_acc {
        assert!((0.0..=1.0).contains(acc), "{name}: {acc}");
    }
}

#[test]
fn fig5_mechanism_absmax_zeros_absorbing() {
    if !have_artifacts() {
        return;
    }
    // dqt_absmax (paper Fig. 5 ablation): max-scale RTN re-quantization —
    // a zero trit can never flip back (needs a half-max single-step
    // update), so the zero set only grows: no accumulation path.
    let vrt =
        with_runtime(|rt| VariantRuntime::load(rt, artifacts_root(), "test-dqt_absmax-b1p58"));
    let Ok(vrt) = vrt else {
        eprintln!("skipping: absmax artifact not built");
        return;
    };
    let pipeline = pipeline_for(&vrt);
    let m = vrt.manifest();
    let loader = pipeline.loader(m.variant.model.batch_size, 5, 42);
    let mut state = vrt.init_state(42).unwrap();
    let grid0 = m.params.iter().position(|p| p.is_grid()).unwrap();
    let mut zero_mask: Vec<bool> = state.params[grid0].iter().map(|&v| v == 0.0).collect();
    let w0_emb = state.params[0].clone();
    while let Some(b) = loader.next() {
        let (s2, _) = vrt
            .train_step(state, &b.tokens, step_seed(42, b.step), 1e-3)
            .unwrap();
        state = s2;
        for (i, &v) in state.params[grid0].iter().enumerate() {
            if zero_mask[i] {
                assert_eq!(v, 0.0, "zero trit revived under RTN at {i}");
            }
            zero_mask[i] = v == 0.0;
        }
    }
    assert_ne!(state.params[0], w0_emb); // embedding still trains
}

#[test]
fn host_and_graph_quantization_agree() {
    if !have_artifacts() {
        return;
    }
    // absmean quantization in rust quant:: must reproduce the grid of the
    // in-graph init for the same dense values — validated indirectly: the
    // init grid re-quantizes to itself under the rust codec.
    let vrt = with_runtime(|rt| VariantRuntime::load(rt, artifacts_root(), "test-dqt-b1p58")).unwrap();
    let state = vrt.init_state(3).unwrap();
    let m = vrt.manifest();
    for (i, meta) in m.params.iter().enumerate() {
        if meta.is_grid() {
            let s = state.params[i + 1][0];
            let again = quant::absmean_quantize(&state.params[i], 1.58, s);
            for (a, b) in state.params[i].iter().zip(again.iter()) {
                assert!((a - b).abs() < 1e-5, "{}", meta.name);
            }
        }
    }
}
