//! Integration tests over real AOT artifacts (require `make artifacts`) —
//! the full L3→PJRT→HLO path: init, train steps, loss decrease, grid
//! invariants, determinism, checkpoint round-trips, ternary inference and
//! the eval harness — plus artifact-free checkpoint/wire-format tests
//! (golden file, corruption handling, packed-grid accounting) that run on
//! a synthetic manifest and exercise the codec registry end to end.

use std::path::PathBuf;

use dqt::data::corpus::CorpusSpec;
use dqt::data::Pipeline;
use dqt::quant::{self, ternary};
use dqt::runtime::artifact::{OptMeta, ParamMeta, TrainStepOutputs, VariantMeta, VariantModelMeta};
use dqt::runtime::{Manifest, Runtime, State, VariantRuntime};
use dqt::train::{checkpoint, step_seed, CosineSchedule, Trainer};
use dqt::config::TrainConfig;

fn artifacts_root() -> PathBuf {
    dqt::default_artifacts_root()
}

fn have_artifacts() -> bool {
    artifacts_root().join("test-dqt-b1p58/manifest.json").is_file()
}

// PjRtClient wraps an Rc (not Send/Sync), so each test thread gets its own
// client via thread_local.
thread_local! {
    static RT: std::rc::Rc<Runtime> =
        std::rc::Rc::new(Runtime::cpu().expect("pjrt cpu client"));
}

fn with_runtime<T>(f: impl FnOnce(&Runtime) -> T) -> T {
    RT.with(|rt| f(rt))
}

fn pipeline_for(vrt: &VariantRuntime) -> Pipeline {
    let m = vrt.manifest();
    Pipeline::build(
        "tiny",
        1,
        m.variant.model.vocab_size,
        m.variant.model.max_seq_len,
    )
    .unwrap()
}

fn train_n(vrt: &VariantRuntime, n: u64, seed: u64) -> (State, Vec<f32>) {
    let pipeline = pipeline_for(vrt);
    let m = vrt.manifest();
    let loader = pipeline.loader(m.variant.model.batch_size, n, seed);
    let sched = CosineSchedule::new(1e-3, 1e-5, 2, n);
    let mut state = vrt.init_state(seed as u32).unwrap();
    let mut losses = Vec::new();
    while let Some(b) = loader.next() {
        let lr = sched.lr(b.step) as f32;
        let (s2, met) = vrt
            .train_step(state, &b.tokens, step_seed(seed, b.step), lr)
            .unwrap();
        state = s2;
        losses.push(met.loss);
    }
    (state, losses)
}

#[test]
fn init_state_matches_manifest_shapes() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let vrt = with_runtime(|rt| VariantRuntime::load(rt, artifacts_root(), "test-dqt-b1p58"))
        .unwrap();
    let m = vrt.manifest();
    let state = vrt.init_state(42).unwrap();
    assert_eq!(state.params.len(), m.params.len());
    assert_eq!(state.opt.len(), m.opt_state.len());
    for (meta, p) in m.params.iter().zip(&state.params) {
        assert_eq!(p.numel(), meta.numel(), "{}", meta.name);
    }
    assert_eq!(state.step(), 0.0);
    // grid invariant at init
    for (i, meta) in m.params.iter().enumerate() {
        if meta.is_grid() {
            let s = state.params[i + 1].scalar().unwrap();
            for &v in state.params[i].values().unwrap().iter() {
                let k = v * s;
                assert!((k - k.round()).abs() < 1e-3, "{} off grid", meta.name);
                assert!((-1.0 - 1e-3..=1.0 + 1e-3).contains(&k));
            }
        }
    }
}

#[test]
fn ternary_training_decreases_loss_and_stays_on_grid() {
    if !have_artifacts() {
        return;
    }
    let vrt = with_runtime(|rt| VariantRuntime::load(rt, artifacts_root(), "test-dqt-b1p58"))
        .unwrap();
    let (state, losses) = train_n(&vrt, 25, 42);
    let head: f32 = losses[..5].iter().sum::<f32>() / 5.0;
    let tail: f32 = losses[losses.len() - 5..].iter().sum::<f32>() / 5.0;
    assert!(tail < head, "loss did not decrease: {head} -> {tail}");
    let m = vrt.manifest();
    for (i, meta) in m.params.iter().enumerate() {
        if meta.is_grid() {
            let s = state.params[i + 1].scalar().unwrap();
            for &v in state.params[i].values().unwrap().iter() {
                let k = v * s;
                assert!((k - k.round()).abs() < 1e-3);
            }
        }
    }
    assert_eq!(state.step(), 25.0);
}

#[test]
fn training_is_deterministic_and_seed_sensitive() {
    if !have_artifacts() {
        return;
    }
    let vrt = with_runtime(|rt| VariantRuntime::load(rt, artifacts_root(), "test-dqt-b1p58"))
        .unwrap();
    let (s1, l1) = train_n(&vrt, 6, 7);
    let (s2, l2) = train_n(&vrt, 6, 7);
    let (_, l3) = train_n(&vrt, 6, 8);
    assert_eq!(l1, l2);
    assert_ne!(l1, l3);
    for (a, b) in s1.params.iter().zip(s2.params.iter()) {
        assert_eq!(a, b);
    }
}

#[test]
fn all_core_modes_train() {
    if !have_artifacts() {
        return;
    }
    for variant in ["test-fp32", "test-bitnet158", "test-dqt-b8"] {
        let vrt = with_runtime(|rt| VariantRuntime::load(rt, artifacts_root(), variant)).unwrap();
        let (_, losses) = train_n(&vrt, 16, 42);
        assert!(losses.iter().all(|l| l.is_finite()), "{variant}");
        // compare head/tail window means — single batches are noisy at
        // test-config scale
        let head: f32 = losses[..4].iter().sum::<f32>() / 4.0;
        let tail: f32 = losses[losses.len() - 4..].iter().sum::<f32>() / 4.0;
        assert!(tail < head, "{variant}: {head} -> {tail}");
    }
}

#[test]
fn trainer_with_dev_eval_and_metrics() {
    if !have_artifacts() {
        return;
    }
    let vrt = with_runtime(|rt| VariantRuntime::load(rt, artifacts_root(), "test-dqt-b1p58"))
        .unwrap();
    let pipeline = pipeline_for(&vrt);
    let cfg = TrainConfig {
        steps: 12,
        warmup_steps: 2,
        peak_lr: 1e-3,
        dataset: "tiny".into(),
        eval_every: 5,
        log_every: 0,
        ..TrainConfig::default()
    };
    let (state, metrics) = Trainer::new(&vrt, &pipeline, cfg).run().unwrap();
    assert_eq!(metrics.records.len(), 12);
    assert!(!metrics.dev_losses.is_empty());
    assert!(metrics.final_dev_loss.unwrap().is_finite());
    assert!(metrics.peak_upd_frac().unwrap() > 0.0);
    assert_eq!(state.step(), 12.0);
}

#[test]
fn checkpoint_roundtrip_and_resume() {
    if !have_artifacts() {
        return;
    }
    let vrt = with_runtime(|rt| VariantRuntime::load(rt, artifacts_root(), "test-dqt-b1p58"))
        .unwrap();
    let m = vrt.manifest();
    let (state, _) = train_n(&vrt, 8, 42);
    let dir = std::env::temp_dir().join("dqt_it_ckpt");
    let path = dir.join("model.dqt");
    checkpoint::save(&path, m, &state, checkpoint::Codec::F32, true).unwrap();
    let loaded = checkpoint::load(&path, m).unwrap();
    // ternary grid packing is lossless
    for (i, (a, b)) in state.params.iter().zip(loaded.params.iter()).enumerate() {
        let (a, b) = (a.values().unwrap(), b.values().unwrap());
        for (x, y) in a.iter().zip(b.iter()) {
            assert!((x - y).abs() < 1e-6, "param {i} ({})", m.params[i].name);
        }
    }
    assert_eq!(loaded.step(), 8.0);
    // packed-grid load: same values, resident at the wire bit width
    let packed = checkpoint::load_packed(&path, m).unwrap();
    for (i, meta) in m.params.iter().enumerate() {
        if meta.is_grid() {
            assert!(packed.params[i].is_packed(), "{}", meta.name);
            assert_eq!(
                packed.params[i].host_bytes(),
                ternary::packed_bytes(meta.numel())
            );
        }
    }
    assert!(packed.grid_param_bytes(m) < packed.host_param_bytes());
    // resumed training continues identically to a state held in memory
    let pipeline = pipeline_for(&vrt);
    let batch = pipeline.loader(m.variant.model.batch_size, 1, 99).next().unwrap();
    let (_, met_mem) = vrt
        .train_step(state, &batch.tokens, step_seed(99, 0), 1e-3)
        .unwrap();
    let (_, met_load) = vrt
        .train_step(loaded, &batch.tokens, step_seed(99, 0), 1e-3)
        .unwrap();
    assert_eq!(met_mem.loss, met_load.loss);
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn packed_checkpoint_sizes_reflect_bit_widths() {
    if !have_artifacts() {
        return;
    }
    let tern = with_runtime(|rt| VariantRuntime::load(rt, artifacts_root(), "test-dqt-b1p58"))
        .unwrap();
    let int8 = with_runtime(|rt| VariantRuntime::load(rt, artifacts_root(), "test-dqt-b8"))
        .unwrap();
    let t_bytes = checkpoint::packed_param_bytes(tern.manifest());
    let i_bytes = checkpoint::packed_param_bytes(int8.manifest());
    let f_bytes = tern.manifest().total_param_values() * 4;
    assert!(t_bytes < i_bytes, "{t_bytes} !< {i_bytes}");
    assert!(i_bytes < f_bytes);
    // the quantized share of the test model is ~63%; packing it at 2 bits
    // must save well over a third overall
    assert!((t_bytes as f64) < f_bytes as f64 * 0.7);
}

#[test]
fn eval_and_ternary_inference_paths() {
    if !have_artifacts() {
        return;
    }
    let vrt = with_runtime(|rt| VariantRuntime::load(rt, artifacts_root(), "test-dqt-b8")).unwrap();
    assert!(vrt.has_ternary_inference());
    let (state, _) = train_n(&vrt, 10, 42);
    let pipeline = pipeline_for(&vrt);
    let ppl8 = dqt::eval::perplexity(&vrt, &state, &pipeline, false).unwrap();
    let ppl3 = dqt::eval::perplexity(&vrt, &state, &pipeline, true).unwrap();
    assert!(ppl8.is_finite() && ppl8 > 1.0);
    assert!(ppl3.is_finite() && ppl3 > 1.0);
    assert_ne!(ppl8, ppl3); // ternary projection must change the model
}

#[test]
fn packed_state_evaluates_identically() {
    if !have_artifacts() {
        return;
    }
    // the PJRT-boundary decode must be invisible to the graphs: a
    // packed-grid state produces the same perplexity as its dense twin
    let vrt = with_runtime(|rt| VariantRuntime::load(rt, artifacts_root(), "test-dqt-b1p58"))
        .unwrap();
    let m = vrt.manifest().clone();
    let (state, _) = train_n(&vrt, 8, 42);
    let pipeline = pipeline_for(&vrt);
    let ppl_dense = dqt::eval::perplexity(&vrt, &state, &pipeline, false).unwrap();
    let mut packed = state.clone();
    packed.pack_grids(&m).unwrap();
    assert!(packed.host_param_bytes() < state.host_param_bytes());
    let ppl_packed = dqt::eval::perplexity(&vrt, &packed, &pipeline, false).unwrap();
    // the grid round-trip is exact in f32, so the two paths agree to
    // floating-point noise at most
    assert!(
        ((ppl_dense - ppl_packed) / ppl_dense).abs() < 1e-5,
        "{ppl_dense} vs {ppl_packed}"
    );
}

#[test]
fn zero_shot_suite_runs_end_to_end() {
    if !have_artifacts() {
        return;
    }
    let vrt = with_runtime(|rt| VariantRuntime::load(rt, artifacts_root(), "test-dqt-b1p58"))
        .unwrap();
    let (state, _) = train_n(&vrt, 10, 42);
    let pipeline = pipeline_for(&vrt);
    let spec = CorpusSpec::tiny(1);
    let r = dqt::eval::evaluate(&vrt, &state, &pipeline, &spec, 12, false, 3).unwrap();
    assert_eq!(r.task_acc.len(), 4);
    for (name, acc) in &r.task_acc {
        assert!((0.0..=1.0).contains(acc), "{name}: {acc}");
    }
}

#[test]
fn fig5_mechanism_absmax_zeros_absorbing() {
    if !have_artifacts() {
        return;
    }
    // dqt_absmax (paper Fig. 5 ablation): max-scale RTN re-quantization —
    // a zero trit can never flip back (needs a half-max single-step
    // update), so the zero set only grows: no accumulation path.
    let vrt =
        with_runtime(|rt| VariantRuntime::load(rt, artifacts_root(), "test-dqt_absmax-b1p58"));
    let Ok(vrt) = vrt else {
        eprintln!("skipping: absmax artifact not built");
        return;
    };
    let pipeline = pipeline_for(&vrt);
    let m = vrt.manifest();
    let loader = pipeline.loader(m.variant.model.batch_size, 5, 42);
    let mut state = vrt.init_state(42).unwrap();
    let grid0 = m.params.iter().position(|p| p.is_grid()).unwrap();
    let mut zero_mask: Vec<bool> =
        state.params[grid0].values().unwrap().iter().map(|&v| v == 0.0).collect();
    let w0_emb = state.params[0].to_vec().unwrap();
    while let Some(b) = loader.next() {
        let (s2, _) = vrt
            .train_step(state, &b.tokens, step_seed(42, b.step), 1e-3)
            .unwrap();
        state = s2;
        for (i, &v) in state.params[grid0].values().unwrap().iter().enumerate() {
            if zero_mask[i] {
                assert_eq!(v, 0.0, "zero trit revived under RTN at {i}");
            }
            zero_mask[i] = v == 0.0;
        }
    }
    assert_ne!(state.params[0].to_vec().unwrap(), w0_emb); // embedding still trains
}

#[test]
fn host_and_graph_quantization_agree() {
    if !have_artifacts() {
        return;
    }
    // absmean quantization in rust quant:: must reproduce the grid of the
    // in-graph init for the same dense values — validated indirectly: the
    // init grid re-quantizes to itself under the rust codec.
    let vrt = with_runtime(|rt| VariantRuntime::load(rt, artifacts_root(), "test-dqt-b1p58"))
        .unwrap();
    let state = vrt.init_state(3).unwrap();
    let m = vrt.manifest();
    for (i, meta) in m.params.iter().enumerate() {
        if meta.is_grid() {
            let s = state.params[i + 1].scalar().unwrap();
            let vals = state.params[i].values().unwrap();
            let again = quant::absmean_quantize(&vals, 1.58, s);
            for (a, b) in vals.iter().zip(again.iter()) {
                assert!((a - b).abs() < 1e-5, "{}", meta.name);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Artifact-free checkpoint & codec-registry tests (synthetic manifest)
// ---------------------------------------------------------------------------

fn pmeta(name: &str, shape: Vec<usize>, role: &str) -> ParamMeta {
    ParamMeta {
        name: name.into(),
        shape,
        dtype: "f32".into(),
        role: Some(role.to_string()),
    }
}

/// A tiny hand-built ternary manifest matching the committed golden file.
fn golden_manifest() -> Manifest {
    Manifest {
        variant: VariantMeta {
            model: VariantModelMeta {
                name: "golden".into(),
                vocab_size: 8,
                hidden_size: 3,
                num_hidden_layers: 1,
                max_seq_len: 4,
                batch_size: 1,
                param_count: 19,
            },
            mode: "dqt".into(),
            bits: 1.58,
            env: "fp32".into(),
            optimizer: "adamw".into(),
            intervention: "none".into(),
            variant_name: "golden".into(),
        },
        params: vec![
            pmeta("emb", vec![2, 3], "dense"),
            pmeta("w0", vec![2, 4], "grid"),
            pmeta("w0.s", vec![], "scale"),
            pmeta("norm", vec![4], "dense"),
        ],
        opt_state: vec![
            OptMeta { name: "step".into(), shape: vec![] },
            OptMeta { name: "m".into(), shape: vec![6] },
        ],
        tokens_shape: vec![1, 4],
        logits_tokens_shape: vec![1, 4],
        pad_id: 0,
        train_step_outputs: TrainStepOutputs {
            n_params: 4,
            n_opt: 2,
            metrics: vec!["loss".into(), "upd_frac".into(), "gnorm".into()],
        },
        entries: vec![],
    }
}

/// The exact state serialized into the golden file (all values chosen to
/// be bit-exact in every involved format).
fn golden_state() -> State {
    State::from_dense(
        vec![
            vec![0.5, -0.25, 1.0, -1.0, 2.0, 0.125],
            vec![0.25, -0.25, 0.0, 0.25, 0.0, -0.25, 0.25, 0.0],
            vec![4.0],
            vec![1.0, 1.0, 1.0, 1.0],
        ],
        vec![vec![3.0], vec![0.0625, -0.0625, 0.5, -0.5, 0.0, 1.0]],
    )
}

const GOLDEN: &[u8] = include_bytes!("golden/golden-ternary.dqt");

#[test]
fn golden_dqt_wire_format_is_stable() {
    // a checkpoint written by the seed implementation (the committed golden
    // file) must be byte-identical to what the codec registry writes today
    let m = golden_manifest();
    let state = golden_state();
    let dir = std::env::temp_dir().join("dqt_golden_ckpt");
    let path = dir.join("golden.dqt");
    checkpoint::save(&path, &m, &state, checkpoint::Codec::F32, true).unwrap();
    let written = std::fs::read(&path).unwrap();
    assert_eq!(
        written, GOLDEN,
        "`.dqt` wire format drifted from the seed encoding"
    );
    // and the golden bytes load back to the exact state
    let loaded = checkpoint::load(&path, &m).unwrap();
    for (a, b) in state.params.iter().zip(loaded.params.iter()) {
        assert_eq!(a.to_vec().unwrap(), b.to_vec().unwrap());
    }
    assert_eq!(loaded.opt, state.opt);
    assert_eq!(loaded.step(), 3.0);
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn load_packed_keeps_wire_bytes_resident() {
    let m = golden_manifest();
    let dir = std::env::temp_dir().join("dqt_golden_packed");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("golden.dqt");
    std::fs::write(&path, GOLDEN).unwrap();
    let st = checkpoint::load_packed(&path, &m).unwrap();
    assert!(st.params[1].is_packed());
    // 8 trits → one packed u32 word
    assert_eq!(st.params[1].host_bytes(), 4);
    assert_eq!(
        st.params[1].to_vec().unwrap(),
        golden_state().params[1].to_vec().unwrap()
    );
    // dense entries stay dense
    assert!(!st.params[0].is_packed());
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn corrupt_checkpoints_error_instead_of_panicking() {
    let m = golden_manifest();
    let dir = std::env::temp_dir().join("dqt_corrupt_ckpt");
    std::fs::create_dir_all(&dir).unwrap();
    let write = |name: &str, bytes: &[u8]| {
        let p = dir.join(name);
        std::fs::write(&p, bytes).unwrap();
        p
    };
    // truncated payload (header claims more bytes than the file holds)
    let p = write("trunc.dqt", &GOLDEN[..GOLDEN.len() - 10]);
    assert!(checkpoint::load(&p, &m).is_err());
    // truncated mid-header
    let p = write("header.dqt", &GOLDEN[..40]);
    assert!(checkpoint::load(&p, &m).is_err());
    // garbage header
    let p = write("garbage.dqt", b"not json at all\nxxxxxxxx");
    assert!(checkpoint::load(&p, &m).is_err());
    // no delimiter
    let p = write("nodelim.dqt", &[0u8, 1, 2, 3]);
    assert!(checkpoint::load(&p, &m).is_err());
    // header/manifest param-count mismatch
    let p = write("ok.dqt", GOLDEN);
    let mut m2 = golden_manifest();
    m2.params.pop();
    assert!(checkpoint::load(&p, &m2).is_err());
    // wrong variant
    let mut m3 = golden_manifest();
    m3.variant.variant_name = "other".into();
    assert!(checkpoint::load(&p, &m3).is_err());
    // the intact file still loads (the guards are not over-eager)
    assert!(checkpoint::load(&p, &m).is_ok());
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn packed_grid_state_accounting_is_16x_under_f32() {
    // acceptance: host-resident bytes of a ternary variant's grid params
    // == ternary::packed_bytes(n), i.e. 16x under dense f32
    let n = 64 * 64;
    let mut m = golden_manifest();
    m.params = vec![pmeta("w0", vec![64, 64], "grid"), pmeta("w0.s", vec![], "scale")];
    let s = 4.0f32;
    let grid: Vec<f32> = (0..n).map(|i| (((i % 3) as f32) - 1.0) / s).collect();
    let mut state = State::from_dense(vec![grid.clone(), vec![s]], vec![vec![0.0]]);
    assert_eq!(state.grid_param_bytes(&m), n * 4);
    state.pack_grids(&m).unwrap();
    assert_eq!(state.grid_param_bytes(&m), ternary::packed_bytes(n));
    assert_eq!(state.grid_param_bytes(&m) * 16, n * 4);
    // the boundary decode reproduces the dense values exactly
    let back = state.params[0].values().unwrap();
    for (a, b) in grid.iter().zip(back.iter()) {
        assert_eq!(a, b);
    }
    // saving from packed mode (zero re-encode fast path) is byte-identical
    // to saving the dense twin
    let dir = std::env::temp_dir().join("dqt_packed_acct");
    let p1 = dir.join("packed.dqt");
    checkpoint::save(&p1, &m, &state, checkpoint::Codec::F32, false).unwrap();
    let mut dense_state = state.clone();
    dense_state.unpack_grids().unwrap();
    let p2 = dir.join("dense.dqt");
    checkpoint::save(&p2, &m, &dense_state, checkpoint::Codec::F32, false).unwrap();
    assert_eq!(std::fs::read(&p1).unwrap(), std::fs::read(&p2).unwrap());
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn save_resolves_scales_by_companion_name_not_position() {
    // a manifest where the `.s` companion is NOT at `i + 1` — the seed's
    // positional assumption would have read the wrong entry
    let mut m = golden_manifest();
    m.params = vec![
        pmeta("w0", vec![2, 4], "grid"),
        pmeta("norm", vec![4], "dense"),
        pmeta("w0.s", vec![], "scale"),
    ];
    let s = 4.0f32;
    let grid: Vec<f32> = (0..8).map(|i| (((i % 3) as f32) - 1.0) / s).collect();
    let state = State::from_dense(
        vec![grid.clone(), vec![1.0, 1.0, 1.0, 1.0], vec![s]],
        vec![vec![0.0], vec![0.0; 6]],
    );
    let dir = std::env::temp_dir().join("dqt_companion_scale");
    let path = dir.join("model.dqt");
    checkpoint::save(&path, &m, &state, checkpoint::Codec::F32, false).unwrap();
    let loaded = checkpoint::load(&path, &m).unwrap();
    for (a, b) in grid.iter().zip(loaded.params[0].values().unwrap().iter()) {
        assert!((a - b).abs() < 1e-6);
    }
    std::fs::remove_dir_all(dir).ok();
}
