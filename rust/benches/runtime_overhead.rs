//! Runtime-layer overhead: how much of a step is host work (literal
//! creation, state marshalling) vs backend execution. §Perf target:
//! non-execute overhead < 5% of step time for t-size models. Also measures
//! the packed-grid boundary decode (`Param::values` on a packed state) so
//! the cost of holding grid params at 2 bits/weight stays visible.
//!
//! The state comes from the native backend (no artifacts needed); the
//! literal marshalling itself exercises the same `lit_f32` path the PJRT
//! boundary uses.

use dqt::config::{Mode, VariantSpec};
use dqt::runtime::{client, Backend, NativeBackend};
use dqt::util::bench::Bench;

fn main() {
    let mut b = Bench::new("runtime_overhead");

    // literal creation throughput (the per-step host cost)
    for n in [1usize << 14, 1 << 18, 1 << 22] {
        let data: Vec<f32> = (0..n).map(|i| i as f32).collect();
        b.bench_bytes(&format!("lit_f32_{n}"), (n * 4) as u64, || {
            client::lit_f32(&data, &[n]).unwrap()
        });
    }

    let backend = NativeBackend::new(&VariantSpec::new("test", Mode::Dqt, 1.58))
        .expect("native backend");
    let m = backend.manifest().clone();
    let state = backend.init_state(1).unwrap();

    let total_bytes = ((m.total_param_values() + m.total_opt_values()) * 4) as u64;
    b.bench_bytes("state_to_literals", total_bytes, || {
        let mut lits = Vec::with_capacity(m.n_state());
        for (meta, p) in m.params.iter().zip(&state.params) {
            lits.push(client::lit_f32(&p.values().unwrap(), &meta.shape).unwrap());
        }
        for (meta, vals) in m.opt_state.iter().zip(&state.opt) {
            lits.push(client::lit_f32(vals, &meta.shape).unwrap());
        }
        lits
    });

    // packed-grid mode: same marshalling, but grid params decode from
    // their 2-bit resident form at the boundary
    let mut packed_state = state.clone();
    packed_state.pack_grids(&m).expect("pack grids");
    eprintln!(
        "param host bytes: dense {} → packed {}",
        state.host_param_bytes(),
        packed_state.host_param_bytes()
    );
    let param_bytes = (m.total_param_values() * 4) as u64;
    b.bench_bytes("packed_state_to_literals", param_bytes, || {
        let mut lits = Vec::with_capacity(m.params.len());
        for (meta, p) in m.params.iter().zip(&packed_state.params) {
            lits.push(client::lit_f32(&p.values().unwrap(), &meta.shape).unwrap());
        }
        lits
    });
}
