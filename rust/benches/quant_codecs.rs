//! Numeric-format codec throughput: ternary/INTn packing, fp8/bf16 casts,
//! host stochastic rounding, and the registry-level `PackedTensor` round
//! trip. §Perf target: ternary pack ≥ 1 GB/s (f32 in); the LUT unpack and
//! streaming INTn pack are tracked against `BENCH_quant_codecs.json`.
//!
//! Runs on the in-tree bench harness (offline build — no criterion).

use dqt::quant::{bf16, fp8, intn, sr, ternary, Format, PackedTensor};
use dqt::util::bench::Bench;

const N: usize = 1 << 20; // 1M weights = 4 MB f32

fn main() {
    let trits: Vec<f32> = (0..N).map(|i| ((i % 3) as f32) - 1.0).collect();
    let floats: Vec<f32> = (0..N).map(|i| (i as f32 - N as f32 / 2.0) * 1e-4).collect();
    let ints: Vec<i32> = (0..N).map(|i| (i % 256) as i32 - 128).collect();
    let i4: Vec<i32> = ints.iter().map(|&v| v.clamp(-8, 7)).collect();
    let bytes = (N * 4) as u64;

    let mut b = Bench::new("quant_codecs");
    b.bench_bytes("ternary_pack_1M", bytes, || ternary::pack(&trits).unwrap());
    let packed = ternary::pack(&trits).unwrap();
    b.bench_bytes("ternary_unpack_1M", bytes, || ternary::unpack(&packed, N));
    b.bench_bytes("int8_pack_1M", bytes, || intn::pack(&ints, 8).unwrap());
    b.bench_bytes("int4_pack_1M", bytes, || intn::pack(&i4, 4).unwrap());
    let packed8 = intn::pack(&ints, 8).unwrap();
    b.bench_bytes("int8_unpack_1M", bytes, || intn::unpack(&packed8, N, 8));
    let packed4 = intn::pack(&i4, 4).unwrap();
    b.bench_bytes("int4_unpack_1M", bytes, || intn::unpack(&packed4, N, 4));
    b.bench_bytes("bf16_cast_1M", bytes, || {
        let mut v = floats.clone();
        bf16::cast_slice(&mut v);
        v
    });
    b.bench_bytes("fp8_e4m3_cast_1M", bytes, || {
        let mut v = floats.clone();
        fp8::cast_slice(&mut v, fp8::Format::E4M3);
        v
    });
    b.bench_bytes("host_sr_1M", bytes, || sr::sr_slice(&floats, 7, 8.0, 100.0));

    // registry-level path: what checkpoint::save / State::pack_grids run
    b.bench_bytes("packed_tensor_ternary_pack_1M", bytes, || {
        PackedTensor::pack(&trits, vec![N], Format::Ternary2bit, Some(1.0)).unwrap()
    });
    let pt = PackedTensor::pack(&trits, vec![N], Format::Ternary2bit, Some(1.0)).unwrap();
    b.bench_bytes("packed_tensor_ternary_unpack_1M", bytes, || {
        pt.unpack().unwrap()
    });
}
