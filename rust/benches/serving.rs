//! Serving-path throughput: prefill tokens/sec, single-stream decode
//! tokens/sec, and batched decode tokens/sec at batch 1/4/16 — the
//! numbers `BENCH_serving.json` tracks (schema enforced by
//! `scripts/check_bench_schema.py`).
//!
//! Runs the decode-free packed-ternary path (2-bit codes + fused GEMV) of
//! the tiny `test` variant on the native backend, so it produces real
//! numbers on any machine. Each `serve_decode_bN` iteration is ONE
//! batched decode step advancing N sequences by one token; the
//! elements-throughput column is therefore aggregate tokens/sec.

use dqt::config::{Mode, VariantSpec};
use dqt::data::Pipeline;
use dqt::runtime::{Decoder, DecoderCache, VariantRuntime};
use dqt::serve::Engine;
use dqt::util::bench::Bench;

fn main() {
    let mut b = Bench::new("serving");
    let spec = VariantSpec::new("test", Mode::Dqt, 1.58);
    let vrt = VariantRuntime::native(&spec).expect("native backend");
    let mut state = vrt.init_state(42).unwrap();
    state.pack_grids(vrt.manifest()).unwrap(); // serve from 2-bit residency
    let m = vrt.manifest();
    let pipeline = Pipeline::build(
        "tiny",
        1,
        m.variant.model.vocab_size,
        m.variant.model.max_seq_len,
    )
    .unwrap();
    let engine = Engine::new(&vrt, &state, pipeline.tokenizer.clone(), false).unwrap();
    let dec = engine.decoder();
    assert_eq!(
        dec.packed_projections(),
        dec.n_projections(),
        "serving bench must exercise the decode-free path"
    );

    // --- prefill: feed a prompt into a fresh cache, tokens/sec ---
    let prompt = engine.prompt_ids("the cat sat on the mat and ran");
    b.bench_elements("serve_prefill", prompt.len() as u64, || {
        let mut cache = dec.new_cache();
        for &t in &prompt {
            dec.step(cache.as_mut(), t).unwrap();
        }
    });

    // --- batched decode: one step over N live sequences per iteration ---
    for batch in [1usize, 4, 16] {
        let mut caches: Vec<Box<dyn DecoderCache>> =
            (0..batch).map(|_| dec.new_cache()).collect();
        let tokens: Vec<i32> = (0..batch).map(|i| (3 + i % 8) as i32).collect();
        b.bench_elements(&format!("serve_decode_b{batch}"), batch as u64, || {
            let mut refs: Vec<&mut dyn DecoderCache> =
                caches.iter_mut().map(|c| &mut **c).collect();
            dec.step_batch(&mut refs[..], &tokens).unwrap()
        });
    }
}
