//! Data pipeline throughput: corpus generation, BPE training/encoding,
//! dataset chunking. §Perf target: tokenizer encode ≥ 10 MB/s.

use dqt::data::corpus::{self, CorpusSpec};
use dqt::data::dataset::Dataset;
use dqt::data::tokenizer::Tokenizer;
use dqt::util::bench::Bench;

fn main() {
    let spec = CorpusSpec::tiny(3);
    let docs = corpus::generate(&spec);
    let text_bytes: usize = docs.iter().map(|d| d.len()).sum();
    let tok = Tokenizer::train(&docs, 512);
    let stream = tok.encode_docs(&docs);

    let mut b = Bench::new("data_pipeline");
    b.bench("corpus_generate_tiny", || corpus::generate(&spec));
    b.bench_bytes("bpe_encode_corpus", text_bytes as u64, || {
        let mut n = 0usize;
        for d in &docs {
            n += tok.encode(d).len();
        }
        n
    });
    b.bench_elements("dataset_chunk_shuffle", stream.len() as u64, || {
        Dataset::from_stream(&stream, 128, 0.01, 7)
    });
    b.bench("bpe_train_512vocab", || Tokenizer::train(&docs, 512));
}
