//! Per-mode train-step latency (the §Perf headline) and the Fig.-2-family
//! cost comparison: fp32 vs bitnet vs dqt-ternary vs dqt-8bit on the same
//! compiled shapes. Uses the `test` config so the bench is quick; e2e
//! numbers for t-size models are recorded in EXPERIMENTS.md.
//!
//! Requires `make artifacts` (core suite).

use dqt::data::Pipeline;
use dqt::runtime::{Runtime, VariantRuntime};
use dqt::train::step_seed;
use dqt::util::bench::Bench;

fn main() {
    let artifacts = dqt::default_artifacts_root();
    if !artifacts.join("index.json").is_file() {
        eprintln!("skipping step_latency: artifacts not built (run `make artifacts`)");
        return;
    }
    let rt = Runtime::cpu().expect("pjrt");
    let mut b = Bench::new("step_latency");

    for variant in [
        "test-fp32",
        "test-bitnet158",
        "test-dqt-b1p58",
        "test-dqt-b8",
    ] {
        let Ok(vrt) = VariantRuntime::load(&rt, &artifacts, variant) else {
            eprintln!("skipping {variant}: artifact missing");
            continue;
        };
        let m = vrt.manifest();
        let tokens_per_step = (m.variant.model.batch_size * m.variant.model.max_seq_len) as u64;
        let pipeline = Pipeline::build(
            "tiny",
            1,
            m.variant.model.vocab_size,
            m.variant.model.max_seq_len,
        )
        .unwrap();
        let loader = pipeline.loader(m.variant.model.batch_size, 1, 1);
        let batch = loader.next().unwrap();
        let state0 = vrt.init_state(42).unwrap();

        let mut state = Some(state0.clone());
        let mut step = 0u64;
        b.bench_elements(&format!("train/{variant}"), tokens_per_step, || {
            let s = state.take().unwrap();
            let (s2, metrics) = vrt
                .train_step(s, &batch.tokens, step_seed(42, step), 1e-3)
                .unwrap();
            step += 1;
            state = Some(s2);
            metrics.loss
        });

        b.bench_elements(&format!("eval/{variant}"), tokens_per_step, || {
            vrt.eval_step(&state0, &batch.tokens, false).unwrap()
        });
        if vrt.has_ternary_inference() {
            b.bench_elements(&format!("eval_ternary/{variant}"), tokens_per_step, || {
                vrt.eval_step(&state0, &batch.tokens, true).unwrap()
            });
        }
    }
}
