//! Per-mode train-step latency (the §Perf headline) and the Fig.-2-family
//! cost comparison: fp32 vs bitnet vs dqt-ternary vs dqt-8bit on the same
//! shapes. Uses the `test` config so the bench is quick; e2e numbers for
//! t-size models are recorded in EXPERIMENTS.md.
//!
//! Runs on whichever backend `BackendKind::Auto` resolves to — the native
//! CPU backend needs no artifacts, so this bench produces real numbers on
//! any machine (PJRT + `make artifacts` switches it to compiled graphs).

use dqt::config::{BackendKind, Mode, VariantSpec};
use dqt::data::Pipeline;
use dqt::runtime::VariantRuntime;
use dqt::train::step_seed;
use dqt::util::bench::Bench;

fn main() {
    let artifacts = dqt::default_artifacts_root();
    let mut b = Bench::new("step_latency");

    let specs = [
        VariantSpec::new("test", Mode::Fp32, 1.58),
        VariantSpec::new("test", Mode::Bitnet158, 1.58),
        VariantSpec::new("test", Mode::Dqt, 1.58),
        VariantSpec::new("test", Mode::Dqt, 8.0),
    ];
    for spec in &specs {
        let variant = spec.variant_name();
        let vrt = match VariantRuntime::open(BackendKind::Auto, None, &artifacts, spec) {
            Ok(v) => v,
            Err(e) => {
                eprintln!("skipping {variant}: {e}");
                continue;
            }
        };
        let m = vrt.manifest();
        let tokens_per_step = (m.variant.model.batch_size * m.variant.model.max_seq_len) as u64;
        let pipeline = Pipeline::build(
            "tiny",
            1,
            m.variant.model.vocab_size,
            m.variant.model.max_seq_len,
        )
        .unwrap();
        let loader = pipeline.loader(m.variant.model.batch_size, 1, 1);
        let batch = loader.next().unwrap();
        let state0 = vrt.init_state(42).unwrap();

        let mut state = Some(state0.clone());
        let mut step = 0u64;
        b.bench_elements(&format!("train/{variant}"), tokens_per_step, || {
            let s = state.take().unwrap();
            let (s2, metrics) = vrt
                .train_step(s, &batch.tokens, step_seed(42, step), 1e-3)
                .unwrap();
            step += 1;
            state = Some(s2);
            metrics.loss
        });

        b.bench_elements(&format!("eval/{variant}"), tokens_per_step, || {
            vrt.eval_step(&state0, &batch.tokens, false).unwrap()
        });
        if vrt.has_ternary_inference() {
            b.bench_elements(&format!("eval_ternary/{variant}"), tokens_per_step, || {
                vrt.eval_step(&state0, &batch.tokens, true).unwrap()
            });
        }
    }
}
