//! Distributed-plane benchmarks: world-2 all-reduce throughput over
//! localhost TCP (MB/s of f32 gradient traffic through the fixed-rank-
//! order tree reduce), and the weight-resync frame sizes — packed grid
//! codes vs f32 — that the memory model's `dist_estimate` predicts.
//! §Perf target: the t130 packed sync ships >10× fewer bytes than f32.

use std::net::TcpListener;
use std::time::Duration;

use dqt::config::{Mode, ModelConfig, VariantSpec};
use dqt::dist::Collective;
use dqt::runtime::VariantRuntime;
use dqt::util::bench::Bench;

fn main() {
    let mut b = Bench::new("dist");

    // --- world-2 all-reduce over loopback, t130-sized f32 gradient set ---
    let n = ModelConfig::by_name("t130").unwrap().param_count() as usize;
    let bytes = (n * 4) as u64;
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let worker = std::thread::spawn(move || {
        let Ok(mut col) = Collective::join(&addr, 1, 2, "bench", Duration::from_secs(30))
        else {
            return;
        };
        let mut grads = vec![Some(vec![1.0f32; n])];
        let (mut nll, mut count) = (0.0f32, 0u64);
        let mut step = 0u64;
        // lockstep with the coordinator until it hangs up
        while col.all_reduce(step, &mut grads, &mut nll, &mut count).is_ok() {
            step += 1;
        }
    });
    {
        let mut col =
            Collective::host(listener, 2, "bench", Duration::from_secs(30)).unwrap();
        let mut grads = vec![Some(vec![1.0f32; n])];
        let (mut nll, mut count) = (0.0f32, 0u64);
        let mut step = 0u64;
        b.bench_bytes("allreduce_w2_t130_f32", bytes, || {
            col.all_reduce(step, &mut grads, &mut nll, &mut count)
                .expect("all-reduce");
            step += 1;
        });
        // dropping the collective hangs up on the worker
    }
    let _ = worker.join();

    // --- weight-resync frames: packed grid codes + scales vs f32 ---
    let vrt = VariantRuntime::native(&VariantSpec::new("t130", Mode::Dqt, 1.58)).unwrap();
    let state = vrt.init_state(1).unwrap();
    let manifest = vrt.manifest();
    let packed_len = Collective::build_grid_sync(manifest, &state, true, 0)
        .unwrap()
        .encode()
        .len() as u64;
    let f32_len = Collective::build_grid_sync(manifest, &state, false, 0)
        .unwrap()
        .encode()
        .len() as u64;
    assert!(
        packed_len * 10 < f32_len,
        "packed sync {packed_len}B should be >10x under f32 sync {f32_len}B"
    );
    println!(
        "dist/grid_sync sizes: packed {packed_len} B vs f32 {f32_len} B \
         ({:.1}x less on the wire)",
        f32_len as f64 / packed_len as f64
    );
    b.bench_bytes("grid_sync_packed_t130", packed_len, || {
        Collective::build_grid_sync(manifest, &state, true, 0)
            .unwrap()
            .encode()
    });
    b.bench_bytes("grid_sync_f32_t130", f32_len, || {
        Collective::build_grid_sync(manifest, &state, false, 0)
            .unwrap()
            .encode()
    });
}
