//! Distributed-plane benchmarks: world-2 all-reduce throughput over
//! localhost TCP — dense f32 gradient traffic vs the `--grad-format`
//! quantized exchange (int8 / ternary stochastically rounded grids
//! through the same fixed-rank-order tree reduce) — and the weight-resync
//! frame sizes that the memory model's `dist_estimate` predicts.
//! §Perf targets: the t130 packed sync ships >10× fewer bytes than f32,
//! a t130 int8 gradient frame is >3.99× smaller than its f32 frame, and
//! a ternary one >10× smaller (both asserted below, frames measured).

use std::net::TcpListener;
use std::time::Duration;

use dqt::config::{Mode, ModelConfig, VariantSpec};
use dqt::dist::{Collective, Frame};
use dqt::quant::{Format, GradCodec};
use dqt::runtime::VariantRuntime;
use dqt::util::bench::Bench;

/// A smooth non-constant gradient stand-in: constant buffers quantize
/// degenerately (every element sits on the absmax), which would flatter
/// the stochastic-rounding path.
fn fake_grads(n: usize) -> Vec<Option<Vec<f32>>> {
    vec![Some((0..n).map(|i| 1e-3 + (i % 97) as f32 * 1e-4).collect())]
}

/// One world-2 quantized all-reduce bench: rank 1 on its own thread,
/// both ranks carrying their own error-feedback codec, lockstep until
/// the coordinator hangs up. The bytes column is the packed payload
/// size, so mean_ns reads as effective gradient-plane MB/s.
fn bench_allreduce_quantized(b: &mut Bench, name: &str, format: Format, n: usize) {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let worker = std::thread::spawn(move || {
        let Ok(mut col) = Collective::join(&addr, 1, 2, "bench", Duration::from_secs(30))
        else {
            return;
        };
        let mut codec = GradCodec::new(format).unwrap();
        let mut grads = fake_grads(n);
        let (mut nll, mut count) = (0.0f32, 0u64);
        let mut step = 0u64;
        while col
            .all_reduce_quantized(step, &mut codec, &mut grads, &mut nll, &mut count)
            .is_ok()
        {
            step += 1;
        }
    });
    {
        let mut col =
            Collective::host(listener, 2, "bench", Duration::from_secs(30)).unwrap();
        let mut codec = GradCodec::new(format).unwrap();
        let mut grads = fake_grads(n);
        let (mut nll, mut count) = (0.0f32, 0u64);
        let mut step = 0u64;
        b.bench_bytes(name, format.packed_bytes(n) as u64, || {
            col.all_reduce_quantized(step, &mut codec, &mut grads, &mut nll, &mut count)
                .expect("quantized all-reduce");
            step += 1;
        });
        // dropping the collective hangs up on the worker
    }
    let _ = worker.join();
}

fn main() {
    let mut b = Bench::new("dist");

    // --- world-2 all-reduce over loopback, t130-sized f32 gradient set ---
    let n = ModelConfig::by_name("t130").unwrap().param_count() as usize;
    let bytes = (n * 4) as u64;
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let worker = std::thread::spawn(move || {
        let Ok(mut col) = Collective::join(&addr, 1, 2, "bench", Duration::from_secs(30))
        else {
            return;
        };
        let mut grads = vec![Some(vec![1.0f32; n])];
        let (mut nll, mut count) = (0.0f32, 0u64);
        let mut step = 0u64;
        // lockstep with the coordinator until it hangs up
        while col.all_reduce(step, &mut grads, &mut nll, &mut count).is_ok() {
            step += 1;
        }
    });
    {
        let mut col =
            Collective::host(listener, 2, "bench", Duration::from_secs(30)).unwrap();
        let mut grads = vec![Some(vec![1.0f32; n])];
        let (mut nll, mut count) = (0.0f32, 0u64);
        let mut step = 0u64;
        b.bench_bytes("allreduce_w2_t130_f32", bytes, || {
            col.all_reduce(step, &mut grads, &mut nll, &mut count)
                .expect("all-reduce");
            step += 1;
        });
        // dropping the collective hangs up on the worker
    }
    let _ = worker.join();

    // --- the same tree, gradients stochastically rounded for the wire ---
    bench_allreduce_quantized(&mut b, "allreduce_w2_t130_int8", Format::IntN(8), n);
    bench_allreduce_quantized(&mut b, "allreduce_w2_t130_ternary", Format::Ternary2bit, n);

    // --- measured gradient frame sizes: quantized vs dense f32 ---
    // One t130-sized single-buffer frame of each shape, actually encoded.
    // The int8 whole-frame ratio approaches exactly 4.0 from below as the
    // per-entry metadata amortizes (1 byte/value vs 4), hence the 3.99
    // floor; ternary (2 bits/value, 16x asymptote) clears 10x easily.
    let grads = fake_grads(n);
    let f32_frame = Frame::GradSet {
        step: 0,
        nll: 1.0,
        count: 1,
        entries: grads.clone(),
    }
    .encode()
    .len() as f64;
    for (format, name, floor) in [
        (Format::IntN(8), "int8", 3.99),
        (Format::Ternary2bit, "ternary", 10.0),
    ] {
        let mut codec = GradCodec::new(format).unwrap();
        let packed = Frame::PackedGradSet {
            step: 0,
            nll: 1.0,
            count: 1,
            format,
            entries: codec.encode_set(0, 0, &grads).unwrap(),
        }
        .encode()
        .len() as f64;
        let ratio = f32_frame / packed;
        assert!(
            ratio > floor,
            "{name} gradient frame is only {ratio:.2}x under f32 ({packed}B vs {f32_frame}B), need >{floor}x"
        );
        println!(
            "dist/grad frame sizes: {name} {packed} B vs f32 {f32_frame} B \
             ({ratio:.2}x less on the wire)"
        );
    }

    // --- weight-resync frames: packed grid codes + scales vs f32 ---
    let vrt = VariantRuntime::native(&VariantSpec::new("t130", Mode::Dqt, 1.58)).unwrap();
    let state = vrt.init_state(1).unwrap();
    let manifest = vrt.manifest();
    let packed_len = Collective::build_grid_sync(manifest, &state, true, 0)
        .unwrap()
        .encode()
        .len() as u64;
    let f32_len = Collective::build_grid_sync(manifest, &state, false, 0)
        .unwrap()
        .encode()
        .len() as u64;
    assert!(
        packed_len * 10 < f32_len,
        "packed sync {packed_len}B should be >10x under f32 sync {f32_len}B"
    );
    println!(
        "dist/grid_sync sizes: packed {packed_len} B vs f32 {f32_len} B \
         ({:.1}x less on the wire)",
        f32_len as f64 / packed_len as f64
    );
    b.bench_bytes("grid_sync_packed_t130", packed_len, || {
        Collective::build_grid_sync(manifest, &state, true, 0)
            .unwrap()
            .encode()
    });
    b.bench_bytes("grid_sync_f32_t130", f32_len, || {
        Collective::build_grid_sync(manifest, &state, false, 0)
            .unwrap()
            .encode()
    });
}
