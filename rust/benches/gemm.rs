//! Kernel-layer GEMM throughput: the blocked dense kernels and the
//! packed-ternary fused GEMM at 1/2/4 pool threads — the numbers
//! `BENCH_kernels.json` tracks (schema enforced by
//! `scripts/check_bench_schema.py`).
//!
//! Each iteration performs one full `[M,K] @ [N,K]ᵀ` product, and the
//! elements-throughput annotation is `2·M·N·K` (multiply-adds counted as
//! two FLOPs), so the reported `elem/s` column reads directly as FLOP/s.
//! The acceptance check for the parallel kernel layer is that
//! `*_gemm_t2` / `*_gemm_t4` mean times drop below `*_gemm_t1` on
//! multi-core hardware — same bits out, fewer nanoseconds.

use dqt::data::corpus::Rng;
use dqt::kernels::{gemm, ternary as ternary_kernels, Pool};
use dqt::quant::ternary;
use dqt::util::bench::Bench;

fn main() {
    let mut b = Bench::new("kernels");
    let fast = std::env::var("DQT_BENCH_FAST").is_ok();
    // odd-ish shapes on purpose: the blocked kernels must not rely on
    // block-aligned dimensions to perform
    let (m, k, n) = if fast { (24, 160, 96) } else { (96, 448, 288) };
    let mut rng = Rng::new(0xD0_77);
    let x: Vec<f32> = (0..m * k).map(|_| rng.next_f64() as f32 - 0.5).collect();
    let w: Vec<f32> = (0..n * k).map(|_| rng.next_f64() as f32 - 0.5).collect();
    let trits: Vec<f32> = (0..n * k).map(|_| rng.below(3) as f32 - 1.0).collect();
    let packed = ternary::pack(&trits).unwrap();
    let dy: Vec<f32> = (0..m * n).map(|_| rng.next_f64() as f32 - 0.5).collect();
    let flops = 2 * (m * n * k) as u64;

    for t in [1usize, 2, 4] {
        let pool = Pool::new(t);
        b.set_threads(t); // records carry the pool actually used
        b.bench_elements(&format!("dense_gemm_t{t}"), flops, || {
            gemm::matmul_nt(&pool, &x, &w, m, k, n)
        });
        b.bench_elements(&format!("ternary_gemm_t{t}"), flops, || {
            ternary_kernels::gemm_nt(&pool, &packed, &x, m, k, n, 1.7)
        });
    }

    // the backward kernels ride along at the widest setting so perf
    // regressions in the gradient path surface here too
    let pool = Pool::new(4);
    b.set_threads(4);
    b.bench_elements("dense_dgrad_t4", flops, || {
        let mut dx = vec![0f32; m * k];
        gemm::add_matmul_nn(&pool, &dy, &w, m, n, k, &mut dx);
        dx
    });
    b.bench_elements("dense_wgrad_t4", flops, || {
        let mut dw = vec![0f32; n * k];
        gemm::add_matmul_tn(&pool, &dy, &x, m, n, k, &mut dw);
        dw
    });
}
