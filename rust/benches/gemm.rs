//! Kernel-layer GEMM throughput: the blocked dense kernels and the
//! packed-ternary fused GEMM at 1/2/4 pool threads — the numbers
//! `BENCH_kernels.json` tracks (schema enforced by
//! `scripts/check_bench_schema.py`).
//!
//! Each iteration performs one full `[M,K] @ [N,K]ᵀ` product, and the
//! elements-throughput annotation is `2·M·N·K` (multiply-adds counted as
//! two FLOPs), so the reported `elem/s` column reads directly as FLOP/s.
//! The acceptance check for the parallel kernel layer is that
//! `*_gemm_t2` / `*_gemm_t4` mean times drop below `*_gemm_t1` on
//! multi-core hardware — same bits out, fewer nanoseconds.
//!
//! The fast tier (`Precision::Fast`) rides the same shapes and ASSERTS
//! its acceptance floors in-process at the end of the run: the
//! activation-block LUT ternary GEMM must be ≥2× faster than the exact
//! packed-ternary GEMM, and the wide multi-accumulator dense kernel ≥1.5×
//! faster than the exact dense kernel, at equal (single) thread count.

use dqt::config::Precision;
use dqt::data::corpus::Rng;
use dqt::kernels::{gemm, ternary as ternary_kernels, Pool};
use dqt::quant::ternary;
use dqt::util::bench::Bench;

fn main() {
    let mut b = Bench::new("kernels");
    let fast = std::env::var("DQT_BENCH_FAST").is_ok();
    // odd-ish shapes on purpose: the blocked kernels must not rely on
    // block-aligned dimensions to perform. Both shapes stay LUT-eligible
    // (k % 4 == 0, n ≥ kernels::ternary::LUT_MIN_CHANNELS) so the
    // `ternary_lut_*` entries measure the table path, not the fallback.
    let (m, k, n) = if fast { (24, 160, 160) } else { (96, 448, 288) };
    let mut rng = Rng::new(0xD0_77);
    let x: Vec<f32> = (0..m * k).map(|_| rng.next_f64() as f32 - 0.5).collect();
    let w: Vec<f32> = (0..n * k).map(|_| rng.next_f64() as f32 - 0.5).collect();
    let trits: Vec<f32> = (0..n * k).map(|_| rng.below(3) as f32 - 1.0).collect();
    let packed = ternary::pack(&trits).unwrap();
    let dy: Vec<f32> = (0..m * n).map(|_| rng.next_f64() as f32 - 0.5).collect();
    let flops = 2 * (m * n * k) as u64;

    for t in [1usize, 2, 4] {
        let pool = Pool::new(t);
        b.set_threads(t); // records carry the pool actually used
        b.bench_elements(&format!("dense_gemm_t{t}"), flops, || {
            gemm::matmul_nt(&pool, &x, &w, m, k, n)
        });
        b.bench_elements(&format!("ternary_gemm_t{t}"), flops, || {
            ternary_kernels::gemm_nt(&pool, &packed, &x, m, k, n, 1.7)
        });
    }

    // fast tier: identical shapes on Precision::Fast pools, so each
    // `*_fast_tN` / `ternary_lut_tN` row is directly comparable to its
    // exact-tier sibling above
    for t in [1usize, 2, 4] {
        let pool = Pool::with_precision(t, Precision::Fast);
        b.set_threads(t);
        b.bench_elements(&format!("dense_gemm_fast_t{t}"), flops, || {
            gemm::matmul_nt(&pool, &x, &w, m, k, n)
        });
        b.bench_elements(&format!("ternary_lut_t{t}"), flops, || {
            ternary_kernels::gemm_nt(&pool, &packed, &x, m, k, n, 1.7)
        });
    }

    // the backward kernels ride along at the widest setting so perf
    // regressions in the gradient path surface here too
    let pool = Pool::new(4);
    b.set_threads(4);
    b.bench_elements("dense_dgrad_t4", flops, || {
        let mut dx = vec![0f32; m * k];
        gemm::add_matmul_nn(&pool, &dy, &w, m, n, k, &mut dx);
        dx
    });
    b.bench_elements("dense_wgrad_t4", flops, || {
        let mut dw = vec![0f32; n * k];
        gemm::add_matmul_tn(&pool, &dy, &x, m, n, k, &mut dw);
        dw
    });

    // acceptance floors for the fast tier, asserted here so the bench job
    // itself fails on a perf regression (equal thread count: t=1 keeps
    // scheduler noise out of the ratio)
    let mean = |name: &str| b.mean_ns(name).expect(name);
    let dense_speedup = mean("dense_gemm_t1") / mean("dense_gemm_fast_t1");
    let ternary_speedup = mean("ternary_gemm_t1") / mean("ternary_lut_t1");
    println!("fast-tier speedup @ t1: dense {dense_speedup:.2}x, ternary {ternary_speedup:.2}x");
    assert!(
        dense_speedup >= 1.5,
        "fast dense kernel below the 1.5x floor over exact at t1: {dense_speedup:.2}x"
    );
    assert!(
        ternary_speedup >= 2.0,
        "LUT ternary GEMM below the 2x floor over exact at t1: {ternary_speedup:.2}x"
    );
}
